"""Data pipeline: deterministic synthetic stream + memmap shards + loader.

Production properties:
  * deterministic & seekable — batch(step) is a pure function of (seed,
    step, shard), so restart-from-checkpoint replays the exact stream
    (no state files needed);
  * per-host sharding — each process reads only its data-parallel slice;
  * background prefetch — a double-buffered thread hides host latency.
"""

from __future__ import annotations

import queue
import threading

import numpy as np


class SyntheticDataset:
    """Deterministic hash-based token stream (infinite, seekable).

    tokens[step, i] = splitmix64(seed, step, i) % vocab — cheap,
    reproducible, and non-degenerate for throughput/loss smoke tests.
    """

    def __init__(self, vocab: int, seq_len: int, batch: int, seed: int = 0):
        self.vocab, self.seq_len, self.batch, self.seed = vocab, seq_len, batch, seed

    def _splitmix(self, x: np.ndarray) -> np.ndarray:
        x = (x + np.uint64(0x9E3779B97F4A7C15)) * np.uint64(0xBF58476D1CE4E5B9)
        x ^= x >> np.uint64(27)
        x *= np.uint64(0x94D049BB133111EB)
        x ^= x >> np.uint64(31)
        return x

    def batch_at(self, step: int) -> dict[str, np.ndarray]:
        n = self.batch * (self.seq_len + 1)
        base = np.uint64(self.seed) * np.uint64(0x100000001B3) + np.uint64(step)
        idx = np.arange(n, dtype=np.uint64) + base * np.uint64(n)
        toks = (self._splitmix(idx) % np.uint64(self.vocab)).astype(np.int32)
        toks = toks.reshape(self.batch, self.seq_len + 1)
        return {"tokens": toks[:, :-1], "targets": toks[:, 1:]}


class MemmapDataset:
    """Flat binary token file (int32), read as (batch, seq+1) windows.

    Seekable: window offsets derive from (step, shard_idx, n_shards).
    """

    def __init__(self, path: str, seq_len: int, batch: int,
                 shard_idx: int = 0, n_shards: int = 1):
        self.tokens = np.memmap(path, dtype=np.int32, mode="r")
        self.seq_len, self.batch = seq_len, batch
        self.shard_idx, self.n_shards = shard_idx, n_shards
        self.n_windows = len(self.tokens) // (seq_len + 1)
        assert self.n_windows >= batch * n_shards, "file too small"

    def batch_at(self, step: int) -> dict[str, np.ndarray]:
        w = self.seq_len + 1
        rows = []
        for i in range(self.batch):
            j = (step * self.batch * self.n_shards
                 + self.shard_idx * self.batch + i) % self.n_windows
            rows.append(np.asarray(self.tokens[j * w:(j + 1) * w]))
        toks = np.stack(rows)
        return {"tokens": toks[:, :-1], "targets": toks[:, 1:]}


class DataLoader:
    """Background-prefetching iterator over a seekable dataset."""

    def __init__(self, dataset, start_step: int = 0, prefetch: int = 2):
        self.dataset = dataset
        self.step = start_step
        self._q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        s = self.step
        while not self._stop.is_set():
            try:
                self._q.put((s, self.dataset.batch_at(s)), timeout=0.2)
                s += 1
            except queue.Full:
                continue

    def __iter__(self):
        return self

    def __next__(self):
        s, b = self._q.get()
        self.step = s + 1
        return b

    def close(self):
        self._stop.set()
        self._thread.join(timeout=2)
