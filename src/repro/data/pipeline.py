"""Data pipeline: deterministic synthetic stream + memmap shards + loader.

Production properties:
  * deterministic & seekable — batch(step) is a pure function of (seed,
    step, shard), so restart-from-checkpoint replays the exact stream
    (no state files needed);
  * per-host sharding — each process reads only its data-parallel slice;
  * background prefetch — a double-buffered thread hides host latency;
  * fail-loud producer (DESIGN.md §11) — an exception in the prefetch
    thread is surfaced to the consumer as a structured
    :class:`ProducerError` on the next ``__next__`` (batches already
    prefetched before the failure are still delivered, in order), never
    a silent hang; ``close()`` is a deterministic, idempotent join.
"""

from __future__ import annotations

import queue
import threading

import numpy as np

from repro.core import faults


class ProducerError(RuntimeError):
    """The DataLoader's prefetch thread died; raised to the consumer.

    Attributes:
        site: the fault-site name (``"pipeline.producer"``).
        step: the dataset step the producer failed at.

    The original exception is chained as ``__cause__``.
    """

    site = "pipeline.producer"

    def __init__(self, step: int, cause: BaseException):
        super().__init__(
            f"data pipeline producer failed at step {step} "
            f"(site {self.site}): {type(cause).__name__}: {cause}"
        )
        self.step = step


class SyntheticDataset:
    """Deterministic hash-based token stream (infinite, seekable).

    tokens[step, i] = splitmix64(seed, step, i) % vocab — cheap,
    reproducible, and non-degenerate for throughput/loss smoke tests.
    """

    def __init__(self, vocab: int, seq_len: int, batch: int, seed: int = 0):
        self.vocab, self.seq_len, self.batch, self.seed = vocab, seq_len, batch, seed

    def _splitmix(self, x: np.ndarray) -> np.ndarray:
        x = (x + np.uint64(0x9E3779B97F4A7C15)) * np.uint64(0xBF58476D1CE4E5B9)
        x ^= x >> np.uint64(27)
        x *= np.uint64(0x94D049BB133111EB)
        x ^= x >> np.uint64(31)
        return x

    def batch_at(self, step: int) -> dict[str, np.ndarray]:
        n = self.batch * (self.seq_len + 1)
        base = np.uint64(self.seed) * np.uint64(0x100000001B3) + np.uint64(step)
        idx = np.arange(n, dtype=np.uint64) + base * np.uint64(n)
        toks = (self._splitmix(idx) % np.uint64(self.vocab)).astype(np.int32)
        toks = toks.reshape(self.batch, self.seq_len + 1)
        return {"tokens": toks[:, :-1], "targets": toks[:, 1:]}


class MemmapDataset:
    """Flat binary token file (int32), read as (batch, seq+1) windows.

    Seekable: window offsets derive from (step, shard_idx, n_shards).
    """

    def __init__(self, path: str, seq_len: int, batch: int,
                 shard_idx: int = 0, n_shards: int = 1):
        self.tokens = np.memmap(path, dtype=np.int32, mode="r")
        self.seq_len, self.batch = seq_len, batch
        self.shard_idx, self.n_shards = shard_idx, n_shards
        self.n_windows = len(self.tokens) // (seq_len + 1)
        assert self.n_windows >= batch * n_shards, "file too small"

    def batch_at(self, step: int) -> dict[str, np.ndarray]:
        w = self.seq_len + 1
        rows = []
        for i in range(self.batch):
            j = (step * self.batch * self.n_shards
                 + self.shard_idx * self.batch + i) % self.n_windows
            rows.append(np.asarray(self.tokens[j * w:(j + 1) * w]))
        toks = np.stack(rows)
        return {"tokens": toks[:, :-1], "targets": toks[:, 1:]}


class DataLoader:
    """Background-prefetching iterator over a seekable dataset.

    Producer failures propagate: if the prefetch thread raises, the
    already-queued batches are still delivered in order, then the next
    ``__next__`` raises :class:`ProducerError` (original exception
    chained) instead of blocking forever.  ``close()`` drains the queue
    so a blocked producer observes the stop promptly, joins the thread,
    and is idempotent; iterating a closed loader raises StopIteration.
    """

    _SENTINEL = object()  # queued after a producer error/stop: wake consumer

    def __init__(self, dataset, start_step: int = 0, prefetch: int = 2):
        self.dataset = dataset
        self.step = start_step
        self.error: ProducerError | None = None
        self._q: queue.Queue = queue.Queue(maxsize=max(prefetch, 1) + 1)
        self._stop = threading.Event()
        self._thread = threading.Thread(
            target=self._worker, daemon=True, name="DataLoader-producer"
        )
        self._thread.start()

    def _worker(self):
        s = self.step
        try:
            while not self._stop.is_set():
                faults.check("pipeline.producer")
                item = (s, self.dataset.batch_at(s))
                while not self._stop.is_set():
                    try:
                        self._q.put(item, timeout=0.05)
                        break
                    except queue.Full:
                        continue
                s += 1
        except Exception as e:  # fail loud: surface on next __next__
            err = ProducerError(s, e)
            err.__cause__ = e
            self.error = err
        finally:
            # Wake a consumer blocked on get(); maxsize=prefetch+1
            # guarantees one sentinel slot beyond the prefetch depth.
            try:
                self._q.put_nowait(self._SENTINEL)
            except queue.Full:
                pass

    def __iter__(self):
        return self

    def __next__(self):
        while True:
            try:
                item = self._q.get(timeout=0.1)
            except queue.Empty:
                if not self._thread.is_alive():
                    if self.error is not None:
                        raise self.error
                    raise StopIteration  # closed/stopped loader
                continue
            if item is self._SENTINEL:
                if self.error is not None:
                    raise self.error
                raise StopIteration
            s, b = item
            self.step = s + 1
            return b

    def close(self):
        """Deterministic, idempotent shutdown: signal stop, drain the
        queue (a producer blocked on a full queue re-checks the stop
        flag within its put timeout), and join the thread."""
        self._stop.set()
        while True:
            try:
                self._q.get_nowait()
            except queue.Empty:
                break
        self._thread.join(timeout=5)
