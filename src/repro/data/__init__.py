from repro.data.pipeline import DataLoader, MemmapDataset, SyntheticDataset

__all__ = ["DataLoader", "MemmapDataset", "SyntheticDataset"]
