"""Version shims for jax APIs that moved between 0.4.x and 0.5+.

Keep every cross-version conditional here so callers (and tests) depend
on one location rather than re-deriving the probe.
"""

from __future__ import annotations

import jax

if hasattr(jax, "shard_map"):  # jax >= 0.5
    shard_map = jax.shard_map
else:  # jax 0.4.x
    from jax.experimental.shard_map import shard_map  # noqa: F401


def mesh_axis_type_kwargs(naxes: int) -> dict:
    """jax >= 0.5 wants explicit AxisType.Auto in jax.make_mesh; older
    jax has no AxisType (everything is Auto implicitly).  Returns kwargs
    valid for the running version."""
    if hasattr(jax.sharding, "AxisType"):
        return dict(axis_types=(jax.sharding.AxisType.Auto,) * naxes)
    return {}
