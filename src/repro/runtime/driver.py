"""Fault-tolerant training runtime.

* auto-resume: state restored from the newest complete checkpoint; the
  seekable data pipeline replays from the exact step (bitwise identical
  batches), so crash -> restart converges to the same trajectory;
* async checkpoints (never blocks the step loop) + keep-k GC + atomic
  rename (no corrupt ckpts on crash mid-write);
* straggler monitor: rolling per-step stats + heartbeat file per host —
  the supervisor side of slow-host eviction at pod scale;
* elastic: ``fit_parallel_to_devices`` re-derives the mesh from the LIVE
  device count so a restart with fewer/more pods keeps running (data
  axis rescales; global batch preserved via grad-accumulation factor).
"""

from __future__ import annotations

import json
import os
import time
from collections import deque

import jax
import numpy as np

from repro import checkpoint
from repro.config import ParallelConfig


class StragglerMonitor:
    """Rolling step-time stats + heartbeat; flags outlier steps/hosts."""

    def __init__(self, window: int = 50, z_thresh: float = 3.0,
                 heartbeat_path: str | None = None):
        self.times: deque[float] = deque(maxlen=window)
        self.z = z_thresh
        self.hb = heartbeat_path
        self.flagged: list[tuple[int, float]] = []

    def record(self, step: int, dt: float) -> bool:
        """Returns True if this step is a straggler outlier."""
        is_straggler = False
        if len(self.times) >= 10:
            mu = float(np.mean(self.times))
            sd = float(np.std(self.times)) + 1e-9
            if dt > mu + self.z * sd and dt > 1.5 * mu:
                is_straggler = True
                self.flagged.append((step, dt))
        self.times.append(dt)
        if self.hb:
            os.makedirs(os.path.dirname(self.hb) or ".", exist_ok=True)
            tmp = self.hb + ".tmp"
            with open(tmp, "w") as f:
                json.dump(
                    {"step": step, "t": time.time(), "dt": dt,
                     "process": jax.process_index()}, f
                )
            os.replace(tmp, self.hb)
        return is_straggler


def fit_parallel_to_devices(p: ParallelConfig, n_devices: int) -> ParallelConfig:
    """Elastic mesh derivation: shrink/grow the data(/pod) axes to match
    the live device count, preserving the model axis."""
    import dataclasses

    shape = dict(zip(p.mesh_axes, p.mesh_shape))
    model = shape.get("model", 1)
    assert n_devices % model == 0, (n_devices, model)
    rest = n_devices // model
    if "pod" in shape:
        pod = shape["pod"]
        while pod > 1 and rest % pod:
            pod //= 2
        shape["pod"], shape["data"] = pod, rest // pod
    else:
        shape["data"] = rest
    new_shape = tuple(shape[a] for a in p.mesh_axes)
    return dataclasses.replace(p, mesh_shape=new_shape)


class TrainDriver:
    """Generic fault-tolerant step loop.

    step_fn: (state, batch) -> (state, metrics dict of scalars)
    dataset: seekable (batch_at(step)) — restart replays deterministically.
    """

    def __init__(self, step_fn, init_state_fn, dataset, *, ckpt_dir: str,
                 ckpt_every: int = 100, ckpt_keep: int = 3,
                 log_every: int = 10, monitor: StragglerMonitor | None = None,
                 state_shardings=None, log_fn=print):
        self.step_fn = step_fn
        self.init_state_fn = init_state_fn
        self.dataset = dataset
        self.ckpt_dir = ckpt_dir
        self.ckpt_every = ckpt_every
        self.log_every = log_every
        self.monitor = monitor or StragglerMonitor()
        self.state_shardings = state_shardings
        self.log = log_fn
        self.ckpt = checkpoint.AsyncCheckpointer(ckpt_dir, keep=ckpt_keep)

    def init_or_restore(self):
        """Returns (state, start_step): restores the newest checkpoint."""
        state = self.init_state_fn()
        step = checkpoint.latest_step(self.ckpt_dir)
        if step is None:
            return state, 0
        self.log(f"[runtime] resuming from checkpoint step {step}")
        state = checkpoint.restore(
            self.ckpt_dir, step, state, shardings=self.state_shardings
        )
        return state, step

    def run(self, total_steps: int, fault_injector=None):
        """Run to total_steps; returns (state, history).  fault_injector
        (step -> None|raise) simulates node failures in tests."""
        state, start = self.init_or_restore()
        history = []
        for step in range(start, total_steps):
            batch = self.dataset.batch_at(step)
            t0 = time.perf_counter()
            if fault_injector is not None:
                fault_injector(step)
            state, metrics = self.step_fn(state, batch)
            jax.block_until_ready(metrics)
            dt = time.perf_counter() - t0
            straggler = self.monitor.record(step, dt)
            if straggler:
                self.log(f"[runtime] straggler step {step}: {dt * 1e3:.1f} ms")
            if step % self.log_every == 0 or step == total_steps - 1:
                m = {k: float(v) for k, v in metrics.items()}
                history.append({"step": step, **m, "dt": dt})
                self.log(f"[train] step {step} {m} ({dt * 1e3:.0f} ms)")
            if self.ckpt_every and (step + 1) % self.ckpt_every == 0:
                self.ckpt.save(step + 1, state)
        self.ckpt.wait()
        if self.ckpt_every and total_steps % self.ckpt_every != 0:
            checkpoint.save(self.ckpt_dir, total_steps, state)
        return state, history
