from repro.runtime.driver import TrainDriver, StragglerMonitor

__all__ = ["TrainDriver", "StragglerMonitor"]
