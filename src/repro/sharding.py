"""Logical-axis sharding rules (MaxText-style) + activation constraints.

Every parameter carries a tuple of LOGICAL axis names; ``resolve`` maps
them to mesh axes through an ordered rule list.  A rule applies only if
(a) its mesh axes are not already used by this tensor and (b) the dim
size is divisible by the mesh axes' total size — so e.g. kv_heads=2
falls through on a 16-way model axis and the ("head_dim", "model")
fallback shards the head dimension instead.

Activations are constrained at key points via ``constrain`` which
no-ops when no mesh context is installed (CPU unit tests).
"""

from __future__ import annotations

import contextlib
import math
import threading

import jax
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P


def default_rules(fsdp: bool, batch_axes=("data",), fsdp_axes=("data",)):
    """PRIORITY-ordered (logical, mesh) rules.

    ``resolve`` walks rules in order (not tensor dims), so earlier
    entries win mesh axes.  Later same-name entries are fallbacks.
    """
    return [
        ("batch", tuple(batch_axes)),
        ("vocab", "model"),
        ("expert", "model"),
        ("heads", "model"),
        ("kv_heads", "model"),
        # sequence-TP attention: when the head counts don't divide the
        # model axis (llama 24H, whisper 20H, minicpm 40H on 16-way TP),
        # shard the attention activations' seq dim instead — local S^2
        # score blocks with one small q/k/v reshard, instead of
        # cross-device partial-sum'd score tensors (measured ~400x less
        # collective traffic on prefill_32k).
        ("qk_seq", "model"),
        ("mlp", "model"),
        ("ssm_inner", "model"),
        ("head_dim", "model"),  # weight-side fallback TP
        # KV-cache sequence dim: prefer the widest free sharding —
        # flash-decode style TP over keys (tiny per-step stats comms)
        # beats sharding tiny KV-head counts or replicating the cache.
        ("kv_seq", ("data", "model")),
        ("kv_seq", "model"),
        ("kv_seq", tuple(batch_axes)),
        ("embed", tuple(fsdp_axes) if fsdp else None),
        ("seq", None),
        ("layers", None),
        ("ssm_state", None),
        ("conv", None),
        ("lora", None),
        # MoE dispatch buffers: capacity rows are independent tokens —
        # shard them over the data axes or every data replica computes
        # the full global expert batch (measured 16x flop inflation).
        ("capacity", tuple(batch_axes)),
    ]


def _axes_tuple(mesh_ax):
    return (mesh_ax,) if isinstance(mesh_ax, str) else tuple(mesh_ax)


def resolve(axes, rules, axis_sizes, shape=None) -> P:
    """Logical axes -> PartitionSpec, walked in RULE-PRIORITY order.

    For each rule (in order), assign its mesh axes to the first
    still-unresolved tensor dim with that logical name, subject to
    (a) mesh-axis reuse and (b) divisibility of the dim size.  Rule
    order therefore expresses preference ACROSS dims (e.g. "shard heads
    over model; only if that fails, shard the attention seq dim").

    axes: tuple of logical names (or None) per dim.
    rules: priority list of (logical, mesh axis | tuple | None).
    axis_sizes: mesh axis name -> size.
    shape: optional concrete dims for divisibility checks.
    """
    used: set[str] = set()
    parts: list = [None] * len(axes)
    resolved = [ax is None for ax in axes]
    for name, mesh_ax in rules:
        if mesh_ax is None:
            continue
        mt = _axes_tuple(mesh_ax)
        for i, ax in enumerate(axes):
            if resolved[i] or ax != name:
                continue
            if any(a in used for a in mt):
                continue
            total = math.prod(axis_sizes.get(a, 1) for a in mt)
            if shape is not None and shape[i] % total != 0:
                continue
            # singleton tuples unwrap to the bare name: identical sharding,
            # and PartitionSpec equality on older jax is not normalized
            parts[i] = mt[0] if len(mt) == 1 else mesh_ax
            used.update(mt)
            resolved[i] = True
    while parts and parts[-1] is None:
        parts.pop()
    return P(*parts)


class _Ctx(threading.local):
    def __init__(self):
        self.mesh = None
        self.rules = None


_CTX = _Ctx()


@contextlib.contextmanager
def sharding_ctx(mesh, rules):
    """Install mesh+rules so models can emit sharding constraints."""
    old = (_CTX.mesh, _CTX.rules)
    _CTX.mesh, _CTX.rules = mesh, list(rules)
    try:
        yield
    finally:
        _CTX.mesh, _CTX.rules = old


def constrain(x, *axes):
    """with_sharding_constraint by logical axes; no-op without context."""
    if _CTX.mesh is None or _CTX.rules is None:
        return x
    sizes = dict(_CTX.mesh.shape)
    spec = resolve(tuple(axes), _CTX.rules, sizes, shape=x.shape)
    return jax.lax.with_sharding_constraint(x, NamedSharding(_CTX.mesh, spec))
