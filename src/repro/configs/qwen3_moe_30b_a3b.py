"""qwen3-moe-30b-a3b [moe]: 48L d=2048 32H (GQA kv=4) vocab=151936.

128 experts, top-8, expert d_ff 768 [hf:Qwen/Qwen3-30B-A3B].  MoE
dispatch = the paper's sample-sort bucket machinery.
"""

from repro.config import ArchConfig, LayerSlot, ModelConfig, MoEConfig
from repro.configs.common import LM_SHAPES, SKIP_FULL_ATTN, smoke_shrink

MODEL = ModelConfig(
    name="qwen3-moe-30b-a3b",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=4,
    d_ff=768,
    vocab=151936,
    rope_theta=1000000.0,
    layer_pattern=(LayerSlot("attn", "moe"),),
    moe=MoEConfig(n_experts=128, top_k=8, d_ff_expert=768,
                  dispatch="sample_sort"),
)

CONFIG = ArchConfig(model=MODEL, shapes=LM_SHAPES, skip_notes=SKIP_FULL_ATTN)
SMOKE = smoke_shrink(MODEL)
