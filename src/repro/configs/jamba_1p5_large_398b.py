"""jamba-1.5-large-398b [hybrid]: 72L d=8192 64H (GQA kv=8) ff=24576
vocab=65536, MoE 16e top-2 [arXiv:2403.19887; hf].

Period-8 layout (attn:mamba = 1:7) with MoE every other layer:
  slot0 attn+dense, slot1..7 mamba, MoE on odd slots (4 MoE / period,
  36 MoE layers total).  398B params; FSDP + bf16 optimizer moments to
  fit 16 GB/chip (DESIGN.md §5).  Hybrid => long_500k RUNS.
"""

from repro.config import ArchConfig, LayerSlot, ModelConfig, MoEConfig, SSMConfig
from repro.configs.common import LM_SHAPES_LONG, smoke_shrink

_PERIOD = tuple(
    LayerSlot("attn" if i == 0 else "mamba", "moe" if i % 2 else "dense")
    for i in range(8)
)

MODEL = ModelConfig(
    name="jamba-1.5-large-398b",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=24576,
    vocab=65536,
    layer_pattern=_PERIOD,
    moe=MoEConfig(n_experts=16, top_k=2, d_ff_expert=24576,
                  dispatch="sample_sort"),
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=64,
                  n_groups=1, chunk=256),
    sub_quadratic=True,
)

CONFIG = ArchConfig(
    model=MODEL, shapes=LM_SHAPES_LONG, fsdp=True, moment_dtype="bfloat16"
)
SMOKE = smoke_shrink(MODEL, n_layers=8)
