"""whisper-large-v3 [audio]: 32+32L d=1280 20H ff=5120 vocab=51866.

Encoder-decoder; conv audio frontend is a STUB (input_specs provides
precomputed frame embeddings, 1500 frames = 30 s) [arXiv:2212.04356].
Decoder learned positions approximated sinusoidally (DESIGN.md).
"""

from repro.config import ArchConfig, ModelConfig
from repro.configs.common import LM_SHAPES, SKIP_FULL_ATTN, smoke_shrink

MODEL = ModelConfig(
    name="whisper-large-v3",
    n_layers=32,
    n_encoder_layers=32,
    d_model=1280,
    n_heads=20,
    n_kv_heads=20,
    d_ff=5120,
    vocab=51866,
    attn_bias=True,
    norm="layernorm",
    activation="gelu",
    tie_embeddings=True,
    encoder_positions=1500,
    frontend="audio",
)

CONFIG = ArchConfig(model=MODEL, shapes=LM_SHAPES, skip_notes=SKIP_FULL_ATTN)
SMOKE = smoke_shrink(MODEL)
