"""starcoder2-15b [dense]: 40L d=6144 48H (GQA kv=4) ff=24576 vocab=49152.

GQA + RoPE; layernorm/GELU with biases per StarCoder2 [arXiv:2402.19173; hf].
"""

from repro.config import ArchConfig, ModelConfig
from repro.configs.common import LM_SHAPES, SKIP_FULL_ATTN, smoke_shrink

MODEL = ModelConfig(
    name="starcoder2-15b",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=4,
    d_ff=24576,
    vocab=49152,
    attn_bias=True,
    norm="layernorm",
    activation="gelu",
    rope_theta=100000.0,
)

CONFIG = ArchConfig(model=MODEL, shapes=LM_SHAPES, skip_notes=SKIP_FULL_ATTN)
SMOKE = smoke_shrink(MODEL)
