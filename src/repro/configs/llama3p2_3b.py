"""llama3.2-3b [dense]: 28L d=3072 24H (GQA kv=8) ff=8192 vocab=128256.

Small llama3: RMSNorm/SwiGLU, RoPE theta 500k, tied embeddings
[hf:meta-llama/Llama-3.2-3B; unverified].
"""

from repro.config import ArchConfig, ModelConfig
from repro.configs.common import LM_SHAPES, SKIP_FULL_ATTN, smoke_shrink

MODEL = ModelConfig(
    name="llama3.2-3b",
    n_layers=28,
    d_model=3072,
    n_heads=24,
    n_kv_heads=8,
    d_ff=8192,
    vocab=128256,
    head_dim=128,
    rope_theta=500000.0,
    tie_embeddings=True,
)

CONFIG = ArchConfig(model=MODEL, shapes=LM_SHAPES, skip_notes=SKIP_FULL_ATTN)
SMOKE = smoke_shrink(MODEL)
