"""mamba2-2.7b [ssm]: 64L d=2560 attn-free vocab=50280 ssm_state=128.

SSD (state-space duality) blocks [arXiv:2405.21060].  Sub-quadratic =>
the long_500k decode cell RUNS for this arch (O(1)-state decode).
"""

from repro.config import ArchConfig, LayerSlot, ModelConfig, SSMConfig
from repro.configs.common import LM_SHAPES_LONG, smoke_shrink

MODEL = ModelConfig(
    name="mamba2-2.7b",
    n_layers=64,
    d_model=2560,
    n_heads=1,  # attention-free; SSD heads derive from ssm config
    n_kv_heads=1,
    d_ff=0,
    vocab=50280,
    layer_pattern=(LayerSlot("mamba", "none"),),
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64,
                  n_groups=1, chunk=256),
    sub_quadratic=True,
)

CONFIG = ArchConfig(model=MODEL, shapes=LM_SHAPES_LONG)
SMOKE = smoke_shrink(MODEL)
