"""qwen2-1.5b [dense]: 28L d=1536 12H (GQA kv=2) ff=8960 vocab=151936.

GQA with QKV bias, tied embeddings [arXiv:2407.10671; hf].
"""

from repro.config import ArchConfig, ModelConfig
from repro.configs.common import LM_SHAPES, SKIP_FULL_ATTN, smoke_shrink

MODEL = ModelConfig(
    name="qwen2-1.5b",
    n_layers=28,
    d_model=1536,
    n_heads=12,
    n_kv_heads=2,
    d_ff=8960,
    vocab=151936,
    attn_bias=True,
    rope_theta=1000000.0,
    tie_embeddings=True,
)

CONFIG = ArchConfig(model=MODEL, shapes=LM_SHAPES, skip_notes=SKIP_FULL_ATTN)
SMOKE = smoke_shrink(MODEL)
