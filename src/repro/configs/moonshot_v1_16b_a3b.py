"""moonshot-v1-16b-a3b [moe]: 48L d=2048 16H (kv=16) vocab=163840.

Moonlight-style MoE: 64 experts, top-6, expert d_ff 1408; MHA + RoPE
[hf:moonshotai/Moonlight-16B-A3B].  Every layer MoE per the assignment
spec.  MoE dispatch = the paper's sample-sort bucket machinery.
"""

from repro.config import ArchConfig, LayerSlot, ModelConfig, MoEConfig
from repro.configs.common import LM_SHAPES, SKIP_FULL_ATTN, smoke_shrink

MODEL = ModelConfig(
    name="moonshot-v1-16b-a3b",
    n_layers=48,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab=163840,
    layer_pattern=(LayerSlot("attn", "moe"),),
    moe=MoEConfig(n_experts=64, top_k=6, d_ff_expert=1408,
                  dispatch="sample_sort"),
)

CONFIG = ArchConfig(model=MODEL, shapes=LM_SHAPES, skip_notes=SKIP_FULL_ATTN)
SMOKE = smoke_shrink(MODEL)
