"""minicpm3-4b [dense]: 62L d=2560 40H ff=6400 vocab=73448 — MLA.

Multi-head Latent Attention (DeepSeek-V2 geometry: q_lora 768,
kv_lora 256, nope 64 / rope 32 / v 64) [hf:openbmb/MiniCPM3-4B; hf].
"""

from repro.config import ArchConfig, LayerSlot, MLAConfig, ModelConfig
from repro.configs.common import LM_SHAPES, SKIP_FULL_ATTN, smoke_shrink

MODEL = ModelConfig(
    name="minicpm3-4b",
    n_layers=62,
    d_model=2560,
    n_heads=40,
    n_kv_heads=40,
    d_ff=6400,
    vocab=73448,
    layer_pattern=(LayerSlot("mla", "dense"),),
    mla=MLAConfig(
        q_lora_rank=768,
        kv_lora_rank=256,
        qk_nope_head_dim=64,
        qk_rope_head_dim=32,
        v_head_dim=64,
    ),
)

CONFIG = ArchConfig(model=MODEL, shapes=LM_SHAPES, skip_notes=SKIP_FULL_ATTN)
SMOKE = smoke_shrink(MODEL)
