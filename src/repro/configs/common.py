"""Shared helpers for architecture configs."""

from __future__ import annotations

import dataclasses

from repro.config import ArchConfig, LayerSlot, ModelConfig

LM_SHAPES = ("train_4k", "prefill_32k", "decode_32k")
LM_SHAPES_LONG = ("train_4k", "prefill_32k", "decode_32k", "long_500k")

SKIP_FULL_ATTN = (
    "long_500k skipped: pure full-attention architecture (O(S) KV per "
    "decode step is fine, but the assignment reserves this cell for "
    "sub-quadratic archs)."
)


def smoke_shrink(cfg: ModelConfig, **over) -> ModelConfig:
    """Reduced same-family config: tiny dims, 1-2 periods, small vocab."""
    pat = cfg.layer_pattern
    base = dict(
        n_layers=2 * len(pat) if len(pat) == 1 else len(pat),
        d_model=64,
        n_heads=4,
        n_kv_heads=max(1, min(cfg.n_kv_heads, 2)),
        d_ff=128,
        vocab=512,
        head_dim=0,
        param_dtype="float32",
        dtype="float32",
        attn_chunk=32,
        remat="none",
        frontend_len=8 if cfg.frontend != "none" else 0,
        encoder_positions=16 if cfg.n_encoder_layers else cfg.encoder_positions,
        n_encoder_layers=2 if cfg.n_encoder_layers else 0,
    )
    if cfg.mla is not None:
        base["mla"] = dataclasses.replace(
            cfg.mla, q_lora_rank=32, kv_lora_rank=16,
            qk_nope_head_dim=16, qk_rope_head_dim=8, v_head_dim=16,
        )
    if cfg.moe is not None:
        base["moe"] = dataclasses.replace(
            cfg.moe, n_experts=8, top_k=min(cfg.moe.top_k, 2), d_ff_expert=64
        )
    if cfg.ssm is not None:
        base["ssm"] = dataclasses.replace(
            cfg.ssm, d_state=16, head_dim=16, chunk=16
        )
    base.update(over)
    return dataclasses.replace(cfg, name=cfg.name + "-smoke", **base)
