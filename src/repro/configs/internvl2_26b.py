"""internvl2-26b [vlm]: 48L d=6144 48H (GQA kv=8) ff=16384 vocab=92553.

InternViT frontend is a STUB (precomputed patch embeddings, 256 tokens
per image after pixel shuffle); backbone = InternLM2-20B geometry
[arXiv:2404.16821; hf].
"""

from repro.config import ArchConfig, ModelConfig
from repro.configs.common import LM_SHAPES, SKIP_FULL_ATTN, smoke_shrink

MODEL = ModelConfig(
    name="internvl2-26b",
    n_layers=48,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,
    vocab=92553,
    frontend="vision",
    frontend_len=256,
)

CONFIG = ArchConfig(model=MODEL, shapes=LM_SHAPES, skip_notes=SKIP_FULL_ATTN)
SMOKE = smoke_shrink(MODEL)
