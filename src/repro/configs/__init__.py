"""Architecture registry: --arch <id> -> ArchConfig (+ reduced SMOKE)."""

from __future__ import annotations

import importlib

ARCHS = {
    "starcoder2-15b": "repro.configs.starcoder2_15b",
    "llama3.2-3b": "repro.configs.llama3p2_3b",
    "qwen2-1.5b": "repro.configs.qwen2_1p5b",
    "minicpm3-4b": "repro.configs.minicpm3_4b",
    "whisper-large-v3": "repro.configs.whisper_large_v3",
    "moonshot-v1-16b-a3b": "repro.configs.moonshot_v1_16b_a3b",
    "qwen3-moe-30b-a3b": "repro.configs.qwen3_moe_30b_a3b",
    "mamba2-2.7b": "repro.configs.mamba2_2p7b",
    "jamba-1.5-large-398b": "repro.configs.jamba_1p5_large_398b",
    "internvl2-26b": "repro.configs.internvl2_26b",
}


def get_config(name: str):
    return importlib.import_module(ARCHS[name]).CONFIG


def get_smoke(name: str):
    return importlib.import_module(ARCHS[name]).SMOKE


def all_archs():
    return list(ARCHS)
