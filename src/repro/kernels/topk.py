"""Pallas TPU kernel: row-wise bitonic top-k (MoE router / sampling).

Sorts each row of an (R, C) score matrix descending with a bitonic
network along the lane axis and emits the first k columns.  C is the
number of experts (64 / 128 for the assigned MoE archs) — small enough
that a full row sort is cheaper than iterative max-extraction, and the
bitonic network is branch-free (same rationale as the paper's Step 2).

Keys arrive already in the canonical descending encoding (the caller
uses a ``descending=True`` key codec, see ``ops.topk``): ascending
canonical order == descending score order, for any supported dtype
including the two-word 64-bit encodings.

Ties broken toward the smaller column index (matches jax.lax.top_k).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.bitonic import as_words, bitonic_network_rows, like_words


def _topk_kernel(*refs, num_words: int, kk: int):
    words = tuple(r[...] for r in refs[:num_words])  # (Rb, C) canonical
    out_word_refs = refs[num_words:2 * num_words]
    io_ref = refs[-1]
    rb, c = words[0].shape
    idx = jax.lax.broadcasted_iota(jnp.int32, (rb, c), 1)
    words, idx = bitonic_network_rows(words, idx)
    for r, w in zip(out_word_refs, as_words(words)):
        r[...] = w[:, :kk]
    io_ref[...] = idx[:, :kk]


@functools.partial(jax.jit, static_argnames=("k", "block_rows", "interpret"))
def topk_desc(
    keys, *, k: int, block_rows: int = 256, interpret: bool = True
):
    """Top-k per row of (R, C) canonical keys where SMALLER canonical
    value == HIGHER score (caller pre-encodes with a descending codec,
    see ops.topk).

    Args:
        keys: (R, C) uint32 canonical key words (bare array or tuple,
            msw first); C a power of two, R a multiple of block_rows.
        k: columns to emit per row.
        block_rows: rows sorted per grid program.
    Returns:
        (top_keys (R, k) in the input key structure, top_idx (R, k)
        int32) — the k smallest canonical keys per row, ties toward the
        smaller column index.
    """
    words = as_words(keys)
    nw = len(words)
    r, c = words[0].shape
    assert all(w.dtype == jnp.uint32 and w.shape == (r, c) for w in words)
    assert r % block_rows == 0, (r, block_rows)
    grid = (r // block_rows,)
    spec_in = pl.BlockSpec((block_rows, c), lambda i: (i, 0))
    spec_out = pl.BlockSpec((block_rows, k), lambda i: (i, 0))
    out = pl.pallas_call(
        functools.partial(_topk_kernel, num_words=nw, kk=k),
        grid=grid,
        in_specs=[spec_in] * nw,
        out_specs=[spec_out] * (nw + 1),
        out_shape=[jax.ShapeDtypeStruct((r, k), jnp.uint32)] * nw
        + [jax.ShapeDtypeStruct((r, k), jnp.int32)],
        interpret=interpret,
    )(*words)
    return like_words(tuple(out[:nw]), keys), out[nw]
