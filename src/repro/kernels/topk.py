"""Pallas TPU kernel: row-wise bitonic top-k (MoE router / sampling).

Sorts each row of an (R, C) score matrix descending with a bitonic
network along the lane axis and emits the first k columns.  C is the
number of experts (64 / 128 for the assigned MoE archs) — small enough
that a full row sort is cheaper than iterative max-extraction, and the
bitonic network is branch-free (same rationale as the paper's Step 2).

Ties broken toward the smaller column index (matches jax.lax.top_k).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.bitonic import bitonic_network_rows


def _topk_kernel(k_ref, ko_ref, io_ref, *, kk: int):
    keys = k_ref[...]  # (Rb, C) canonical uint32, ascending == descending score
    rb, c = keys.shape
    idx = jax.lax.broadcasted_iota(jnp.int32, (rb, c), 1)
    keys, idx = bitonic_network_rows(keys, idx)
    ko_ref[...] = keys[:, :kk]
    io_ref[...] = idx[:, :kk]


@functools.partial(jax.jit, static_argnames=("k", "block_rows", "interpret"))
def topk_desc(
    keys: jax.Array, *, k: int, block_rows: int = 256, interpret: bool = True
):
    """Top-k per row of (R, C) canonical-uint32 keys where SMALLER canonical
    value == HIGHER score (caller pre-inverts, see ops.topk).

    Returns (top_keys (R, k) uint32, top_idx (R, k) int32).
    R must be a multiple of block_rows; C a power of two.
    """
    r, c = keys.shape
    assert keys.dtype == jnp.uint32
    assert r % block_rows == 0, (r, block_rows)
    grid = (r // block_rows,)
    return pl.pallas_call(
        functools.partial(_topk_kernel, kk=k),
        grid=grid,
        in_specs=[pl.BlockSpec((block_rows, c), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((block_rows, k), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, k), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((r, k), jnp.uint32),
            jax.ShapeDtypeStruct((r, k), jnp.int32),
        ],
        interpret=interpret,
    )(keys)
