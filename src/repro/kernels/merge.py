"""Merge-path local sort: sorted-run formation + pairwise run merging.

The "merge" entry of the hybrid strategy dispatch (DESIGN.md §8).  The
parallel-sort comparisons (arXiv 1511.03404) show merge-based local
sorts winning on nearly-sorted data: once runs are formed, a merge
level moves every element at most once, whereas the bitonic network
always runs its full O(T log^2 T) compare-exchange schedule.

Algorithm per (block_rows, T) block:

  1. RUN FORMATION: reshape each row into T/r0 runs of ``merge_run``
     elements and sort them with the bitonic network (payload tiebreak
     — runs inherit the full lexicographic order).
  2. MERGE LEVELS: for L = r0, 2*r0, ... < T, merge adjacent run pairs
     (A, B) of length L with MERGE-PATH DIAGONAL PARTITIONING: every
     destination slot p binary-searches its split a in [max(0, p-L),
     min(p, L)] along the diagonal a + b = p — ``ceil(log2(L+2))``
     guarded lexicographic probes — then gathers its source element.
     Scatter-free, O(T log T / log(r0)-ish) data movement, and each
     level is a batched two-pointer merge with NO sequential scan.

Ties go to A (the left run), which preserves stability: the merge is a
STABLE sort keyed on the key words ONLY, the same STRATEGY CONTRACT as
kernels/radix.py — the int32 payload rides along but is not compared
in the merge levels, so callers must supply payloads that increase
within equal keys (the pipeline executor guarantees this; `arange`
payload rows satisfy it trivially).

The pure-jnp formulation below is BOTH the Pallas kernel body (via
``bitonic.tile_sort_call``) and the differential-test reference.  The
xla path uses a documented STAND-IN (the ref.py precedent): runs are
formed with the composite-key radix passes of kernels/radix.py and
merged with bitonic-merge network stages (reverse the right run, then
log2(2L) all-ascending compare-exchange passes with payload tiebreak)
— measured faster than both the two-key ``lax.sort`` oracle and the
full bitonic network on CPU at (256, 4096) tiles.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.bitonic import (
    as_words,
    bitonic_network_rows,
    lex_gt,
    like_words,
    tile_sort_call,
)


def _merge_level(parts, run: int):
    """One merge level: every adjacent pair of sorted length-``run``
    runs in each (rows, T) row of ``parts`` (key words + payload) is
    merged via merge-path diagonal search.  Key-words-only comparison,
    ties to the left run (stable)."""
    words, vals = parts[:-1], parts[-1]
    rows, t = words[0].shape
    pairs = t // (2 * run)
    # Flatten run pairs into rows: (rows * pairs, 2*run).
    wr = [w.reshape(rows * pairs, 2 * run) for w in words]
    vr = vals.reshape(rows * pairs, 2 * run)
    a_w = [w[:, :run] for w in wr]
    b_w = [w[:, run:] for w in wr]
    p = jax.lax.broadcasted_iota(jnp.int32, (rows * pairs, 2 * run), 1)

    def probe(side, idx):
        return [jnp.take_along_axis(w, idx, axis=1) for w in side]

    # Diagonal binary search: find a = #elements taken from A for slot p.
    lo = jnp.maximum(0, p - run)
    hi = jnp.minimum(p, run)
    for _ in range((run + 1).bit_length()):
        mid = (lo + hi) >> 1
        bidx = p - mid - 1
        a_v = probe(a_w, jnp.minimum(mid, run - 1))
        b_v = probe(b_w, jnp.clip(bidx, 0, run - 1))
        take_a = ~lex_gt(a_v, b_v)  # A[mid] <= B[bidx]: ties to A
        take_a = jnp.where(bidx >= run, True, take_a)
        take_a = jnp.where((mid >= run) | (bidx < 0), False, take_a)
        upd = lo < hi
        lo = jnp.where(upd & take_a, mid + 1, lo)
        hi = jnp.where(upd & ~take_a, mid, hi)
    a = lo
    b = p - a
    a_v = probe(a_w, jnp.minimum(a, run - 1))
    b_v = probe(b_w, jnp.clip(b, 0, run - 1))
    take_a = (b >= run) | ((a < run) & ~lex_gt(a_v, b_v))
    src = jnp.where(
        take_a, jnp.minimum(a, run - 1), run + jnp.clip(b, 0, run - 1)
    )
    out = [
        jnp.take_along_axis(x, src, axis=1).reshape(rows, t)
        for x in wr + [vr]
    ]
    return out


def merge_sort_rows(keys, vals: jax.Array, *, merge_run: int = 512):
    """Stable merge-path sort of each row of (rows, T): bitonic-network
    run formation + merge-path levels (the shared strategy formulation:
    Pallas kernel body AND reference implementation).

    Args:
        keys: (rows, T) uint32 word array or tuple (msw first); T a
            power of two.
        vals: (rows, T) int32 payloads (compared only inside the run
            formation; the merge levels carry them — strategy contract).
        merge_run: initial run length r0 (clamped to T).
    Returns:
        (sorted keys in the input structure, payloads moved alongside).
    """
    words = as_words(keys)
    rows, t = words[0].shape
    assert t & (t - 1) == 0, t
    r0 = min(merge_run, t)
    if r0 > 1:
        wr = tuple(w.reshape(-1, r0) for w in words)
        vr = vals.reshape(-1, r0)
        wr, vr = bitonic_network_rows(wr, vr)
        words = tuple(w.reshape(rows, t) for w in wr)
        vals = vr.reshape(rows, t)
    parts = list(words) + [vals]
    run = r0
    while run < t:
        parts = _merge_level(parts, run)
        run *= 2
    return like_words(tuple(parts[:-1]), keys), parts[-1]


# ----------------------------------------------------------------------
# Pallas entry points (mirror kernels/bitonic.py)
# ----------------------------------------------------------------------


@functools.partial(
    jax.jit, static_argnames=("merge_run", "block_rows", "interpret")
)
def sort_tiles_kv(
    keys,
    vals: jax.Array,
    *,
    merge_run: int = 512,
    block_rows: int | None = None,
    interpret: bool = True,
):
    """Row-blocked Pallas merge-path sort of (m, T) tiles
    (strategy="merge").  Args/Returns: as ``bitonic.sort_tiles_kv``."""
    words = as_words(keys)
    out = tile_sort_call(
        words, vals, 0, block_rows, interpret,
        sort_rows=functools.partial(merge_sort_rows, merge_run=merge_run),
    )
    return like_words(tuple(out[:-1]), keys), out[-1]


@functools.partial(
    jax.jit,
    static_argnames=("num_samples", "merge_run", "block_rows", "interpret"),
)
def sort_tiles_sample_kv(
    keys,
    vals: jax.Array,
    *,
    num_samples: int,
    merge_run: int = 512,
    block_rows: int | None = None,
    interpret: bool = True,
):
    """Merge-path tile sort with the Step-3 sample epilogue fused in
    (same layout contract as ``bitonic.sort_tiles_sample_kv``)."""
    words = as_words(keys)
    nw = len(words)
    out = tile_sort_call(
        words, vals, num_samples, block_rows, interpret,
        sort_rows=functools.partial(merge_sort_rows, merge_run=merge_run),
    )
    return (
        like_words(tuple(out[:nw]), keys),
        out[nw],
        like_words(tuple(out[nw + 1:2 * nw + 1]), keys),
        out[2 * nw + 1],
    )


# ----------------------------------------------------------------------
# xla stand-in: composite run formation + bitonic-merge network stages
# ----------------------------------------------------------------------


def _bitonic_merge_stage(parts, run: int):
    """Merge adjacent sorted run pairs with the bitonic merge network:
    reverse the right run of each pair (making each 2*run window a
    bitonic sequence), then log2(2*run) all-ascending compare-exchange
    passes.  Comparison is lexicographic on (*words, payload), which
    both resolves ties deterministically and lands exactly on the
    stable order (the pipeline's payload invariant)."""
    rows = parts[0].shape[0]
    width = 2 * run
    rs = []
    for x in parts:
        q = x.reshape(rows, -1, width)
        rs.append(
            jnp.concatenate([q[:, :, :run], q[:, :, run:][:, :, ::-1]], axis=2)
        )
    d = run
    while d >= 1:
        q3 = [q.reshape(rows, -1, width // (2 * d), 2, d) for q in rs]
        los = [q[..., 0, :] for q in q3]
        his = [q[..., 1, :] for q in q3]
        gt = lex_gt(los, his)
        rs = [
            jnp.stack(
                (jnp.where(gt, hi, lo), jnp.where(gt, lo, hi)), axis=-2
            ).reshape(rows, -1, width)
            for lo, hi in zip(los, his)
        ]
        d //= 2
    t = parts[0].shape[1]
    return [q.reshape(rows, t) for q in rs]


def hybrid_sort_rows(keys, vals: jax.Array, *, merge_run: int = 512):
    """The documented xla STAND-IN for the merge strategy (module
    docstring): composite-key radix run formation + bitonic-merge
    network stages with payload tiebreak."""
    from repro.kernels import radix as _radix

    words = as_words(keys)
    rows, t = words[0].shape
    if t == 1:
        return like_words(words, keys), vals
    assert t & (t - 1) == 0, t
    r0 = min(merge_run, t)
    if r0 > 1:
        wr = tuple(w.reshape(-1, r0) for w in words)
        vr = vals.reshape(-1, r0)
        wr, vr = _radix.composite_sort_rows(wr, vr)
        words = tuple(w.reshape(rows, t) for w in as_words(wr))
        vals = vr.reshape(rows, t)
    parts = list(words) + [vals]
    run = r0
    while run < t:
        parts = _bitonic_merge_stage(parts, run)
        run *= 2
    return like_words(tuple(parts[:-1]), keys), parts[-1]


def hybrid_sort_sample_rows(keys, vals: jax.Array, *, num_samples: int,
                            merge_run: int = 512):
    """Stand-in for the fused sort+sample entry: hybrid merge sort, then
    the s equidistant samples by reshape + slice (as ref.py)."""
    sk, sv = hybrid_sort_rows(keys, vals, merge_run=merge_run)
    words = as_words(sk)
    m, t = words[0].shape
    assert t % num_samples == 0, (t, num_samples)
    chunk = t // num_samples
    samples = tuple(
        a.reshape(m, num_samples, chunk)[:, :, -1] for a in words + (sv,)
    )
    return sk, sv, like_words(tuple(samples[:-1]), keys), samples[-1]
