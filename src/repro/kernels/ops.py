"""Public jit'd entry points for the Pallas kernels.

Handles: dtype canonicalization to totally-ordered uint32 sort keys,
pallas-vs-xla implementation dispatch, and interpret-mode selection
(Pallas kernels run interpret=True on the CPU container, natively on TPU).

Canonical key transform (the classic radix trick):
  int32   -> bitcast ^ 0x8000_0000                  (INT_MIN -> 0)
  uint32  -> identity
  float32 -> bitcast; if sign bit: ~u else u | 0x8000_0000
             (total order: -NaN < -inf < ... < -0 < +0 < ... < +inf < +NaN)
  bf16/f16 -> upcast to f32 first (order-preserving).
"""

from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.kernels import bitonic as _bitonic
from repro.kernels import ref as _ref
from repro.kernels import splitter as _splitter
from repro.kernels import topk as _topk

_SIGN = jnp.uint32(0x80000000)


def default_interpret() -> bool:
    """Pallas interpret mode: emulate on CPU, native on TPU."""
    return jax.default_backend() != "tpu"


def default_impl() -> str:
    env = os.environ.get("REPRO_SORT_IMPL")
    if env in ("pallas", "xla"):
        return env
    return "pallas" if jax.default_backend() == "tpu" else "xla"


def to_sortable(x: jax.Array) -> jax.Array:
    """Map x to uint32 whose unsigned order == the natural order of x."""
    dt = x.dtype
    if dt in (jnp.bfloat16, jnp.float16):
        x = x.astype(jnp.float32)
        dt = jnp.dtype(jnp.float32)
    if dt == jnp.uint32:
        return x
    if dt == jnp.int32:
        return jax.lax.bitcast_convert_type(x, jnp.uint32) ^ _SIGN
    if dt == jnp.float32:
        u = jax.lax.bitcast_convert_type(x, jnp.uint32)
        return jnp.where((u & _SIGN) != 0, ~u, u | _SIGN)
    raise TypeError(f"unsupported sort key dtype {dt}")


def from_sortable(u: jax.Array, dtype) -> jax.Array:
    """Inverse of to_sortable (into int32/uint32/float32)."""
    dtype = jnp.dtype(dtype)
    if dtype == jnp.uint32:
        return u
    if dtype == jnp.int32:
        return jax.lax.bitcast_convert_type(u ^ _SIGN, jnp.int32)
    if dtype in (jnp.dtype(jnp.float32), jnp.dtype(jnp.bfloat16), jnp.dtype(jnp.float16)):
        f = jnp.where((u & _SIGN) != 0, u & ~_SIGN, ~u)
        f32 = jax.lax.bitcast_convert_type(f, jnp.float32)
        return f32.astype(dtype)
    raise TypeError(f"unsupported sort key dtype {dtype}")


def sort_tiles(
    keys: jax.Array,
    vals: jax.Array,
    *,
    impl: str | None = None,
    interpret: bool | None = None,
    block_rows: int | None = None,
):
    """Sort each row of (m, T) canonical-uint32 keys (+int32 payload).

    block_rows: tiles per grid program on the pallas path (None = auto
    VMEM fill, see bitonic.auto_block_rows); ignored on the xla path.
    """
    impl = impl or default_impl()
    if impl == "pallas":
        interpret = default_interpret() if interpret is None else interpret
        return _bitonic.sort_tiles_kv(
            keys, vals, block_rows=block_rows, interpret=interpret
        )
    return _ref.sort_tiles_kv(keys, vals)


def sort_tiles_sample(
    keys: jax.Array,
    vals: jax.Array,
    *,
    num_samples: int,
    impl: str | None = None,
    interpret: bool | None = None,
    block_rows: int | None = None,
):
    """Fused Steps 2+3: sorted (m, T) tiles plus the s equidistant
    per-tile samples, from one read of the tiles.

    Returns (sorted_keys, sorted_vals, sample_keys (m, s), sample_vals).
    """
    impl = impl or default_impl()
    if impl == "pallas":
        interpret = default_interpret() if interpret is None else interpret
        return _bitonic.sort_tiles_sample_kv(
            keys,
            vals,
            num_samples=num_samples,
            block_rows=block_rows,
            interpret=interpret,
        )
    return _ref.sort_tiles_sample_kv(keys, vals, num_samples=num_samples)


def splitter_ranks(
    keys, vals, sp_keys, sp_vals, *, impl: str | None = None,
    interpret: bool | None = None,
):
    """(m, S) rank of each splitter in each tile (canonical uint32 keys)."""
    impl = impl or default_impl()
    if impl == "pallas":
        interpret = default_interpret() if interpret is None else interpret
        return _splitter.splitter_ranks(
            keys, vals, sp_keys, sp_vals, interpret=interpret
        )
    return _ref.splitter_ranks(keys, vals, sp_keys, sp_vals)


def splitter_partition(
    keys, vals, sp_keys, sp_vals, *, impl: str | None = None,
    interpret: bool | None = None, block_rows: int | None = None,
):
    """Fused Steps 6+7 epilogue: (ranks (m, S), counts (m, S+1)) per tile
    from one read of the tiles (canonical uint32 keys)."""
    impl = impl or default_impl()
    if impl == "pallas":
        interpret = default_interpret() if interpret is None else interpret
        return _splitter.splitter_partition(
            keys, vals, sp_keys, sp_vals,
            block_rows=block_rows, interpret=interpret,
        )
    return _ref.splitter_partition(keys, vals, sp_keys, sp_vals)


def topk(
    x: jax.Array,
    k: int,
    *,
    impl: str | None = None,
    interpret: bool | None = None,
):
    """Row-wise top-k (descending) of (R, C) scores.

    Returns (values (R, k) in x.dtype, indices (R, k) int32); ties toward
    the smaller index, matching jax.lax.top_k.  Non-power-of-two C
    (real vocab sizes: 50257, 151936, ...) is padded up with worst-score
    columns, which can never enter the top-k since k <= C.
    """
    impl = impl or default_impl()
    orig_dtype = x.dtype
    u = ~to_sortable(x)  # ascending canonical == descending score
    r, c = u.shape
    assert 1 <= k <= c, (k, c)
    cp = 1
    while cp < c:
        cp *= 2
    if cp > c:  # inverted domain: MAXU == the worst possible score
        u = jnp.concatenate(
            [u, jnp.full((r, cp - c), jnp.uint32(0xFFFFFFFF))], axis=1
        )
        c = cp
    if impl == "pallas":
        interpret = default_interpret() if interpret is None else interpret
        block_rows = _bitonic.largest_pow2_divisor(r, 256)
        tk, ti = _topk.topk_desc(
            u, k=k, block_rows=block_rows, interpret=interpret
        )
    else:
        tk, ti = _ref.topk_desc(u, k=k)
    return from_sortable(~tk, orig_dtype), ti
