"""Public jit'd entry points for the Pallas kernels.

Handles: key-codec encoding to totally-ordered uint32 word tuples
(``core/key_codec`` — one word for <= 32-bit dtypes, hi/lo pairs for
64-bit), pallas-vs-xla implementation dispatch, and interpret-mode
selection (Pallas kernels run interpret=True on the CPU container,
natively on TPU).

Every kernel entry accepts keys either as a bare uint32 array (the
one-word fast path, bit-compatible with the pre-codec API) or as a
tuple of canonical uint32 word arrays (most significant first), and
returns keys in the same structure.  ``to_sortable``/``from_sortable``
remain as one-word convenience shims over the codec layer for the
legacy 32-bit dtypes.
"""

from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.core import faults
from repro.core.key_codec import codec_for
from repro.kernels import bitonic as _bitonic
from repro.kernels import merge as _merge
from repro.kernels import radix as _radix
from repro.kernels import ref as _ref
from repro.kernels import splitter as _splitter
from repro.kernels import topk as _topk
from repro.kernels.bitonic import as_words

_STRATEGIES = ("bitonic", "radix", "merge")


def _check_strategy(strategy: str) -> None:
    if strategy not in _STRATEGIES:
        raise ValueError(
            f"unknown local-sort strategy {strategy!r}; "
            f"expected one of {_STRATEGIES}"
        )


def default_interpret() -> bool:
    """Pallas interpret-mode default.

    Returns:
        True off-TPU (kernels emulate on CPU), False on TPU (native).
    """
    return jax.default_backend() != "tpu"


def default_impl() -> str:
    """Kernel implementation default.

    Returns:
        The ``REPRO_SORT_IMPL`` env var if set to "pallas"/"xla", else
        "pallas" on TPU and "xla" (pure-jnp oracles) elsewhere.
    """
    env = os.environ.get("REPRO_SORT_IMPL")
    if env in ("pallas", "xla"):
        return env
    return "pallas" if jax.default_backend() == "tpu" else "xla"


def to_sortable(x: jax.Array) -> jax.Array:
    """Map x to ONE uint32 word whose unsigned order == x's natural order.

    One-word convenience shim over :func:`repro.core.key_codec.codec_for`
    for the legacy 32-bit dtypes (int32/uint32/float32, bf16/f16 widened).
    64-bit dtypes need two words: use the codec API directly.

    Args:
        x: array of a one-word dtype.
    Returns:
        uint32 array of x's shape.
    Raises:
        TypeError: for unsupported or two-word dtypes.
    """
    codec = codec_for(x.dtype)
    if codec.num_words != 1:
        raise TypeError(
            f"{codec.dtype_name} keys encode to {codec.num_words} words; "
            "use repro.core.key_codec.codec_for(...).encode"
        )
    return codec.encode(x)[0]


def from_sortable(u: jax.Array, dtype) -> jax.Array:
    """Inverse of :func:`to_sortable` (one-word dtypes only).

    Args:
        u: uint32 canonical keys.
        dtype: target one-word dtype (int32/uint32/float32, widened
            bool/8/16-bit floats and ints).
    Returns:
        Array of ``dtype`` with the natural order of the uint32 input.
    Raises:
        TypeError: for unsupported or two-word (64-bit) dtypes.
    """
    codec = codec_for(dtype)
    if codec.num_words != 1:
        raise TypeError(
            f"{codec.dtype_name} keys decode from {codec.num_words} words; "
            "use repro.core.key_codec.codec_for(...).decode"
        )
    return codec.decode((u,))


def sort_tiles(
    keys,
    vals: jax.Array,
    *,
    impl: str | None = None,
    interpret: bool | None = None,
    block_rows: int | None = None,
    strategy: str = "bitonic",
    radix_bits: int = 4,
    merge_run: int = 512,
):
    """Sort each row of (m, T) canonical keys (+int32 payload).

    Args:
        keys: (m, T) uint32 word array or tuple of word arrays (msw
            first, see ``core/key_codec``); T a power of two.
        vals: (m, T) int32 payloads (original indices for stability).
        impl: "pallas" | "xla" | None (auto via :func:`default_impl`).
        interpret: Pallas interpret mode (None = auto: True off-TPU).
        block_rows: tiles per grid program on the pallas path (None =
            auto VMEM fill, see bitonic.auto_block_rows); ignored on xla.
        strategy: local-sort algorithm — "bitonic" (network), "radix"
            (LSD rank-gather, kernels/radix.py) or "merge" (merge-path,
            kernels/merge.py).  DESIGN.md §8; the non-bitonic
            strategies are STABLE key-words-only sorts and require
            payloads increasing within equal keys (the pipeline
            invariant; arange payload rows satisfy it).
        radix_bits / merge_run: strategy knobs (see SortConfig).
    Returns:
        (sorted keys in the input structure, sorted vals), each row
        lexicographically ascending on (*words, payload).
    """
    impl = impl or default_impl()
    _check_strategy(strategy)
    faults.check("kernel.launch")  # trace-time chaos site (DESIGN.md §11)
    if impl == "pallas":
        interpret = default_interpret() if interpret is None else interpret
        if strategy == "radix":
            return _radix.sort_tiles_kv(
                keys, vals, radix_bits=radix_bits, block_rows=block_rows,
                interpret=interpret,
            )
        if strategy == "merge":
            return _merge.sort_tiles_kv(
                keys, vals, merge_run=merge_run, block_rows=block_rows,
                interpret=interpret,
            )
        return _bitonic.sort_tiles_kv(
            keys, vals, block_rows=block_rows, interpret=interpret
        )
    if strategy == "radix":
        return _radix.composite_sort_rows(keys, vals)
    if strategy == "merge":
        return _merge.hybrid_sort_rows(keys, vals, merge_run=merge_run)
    return _ref.sort_tiles_kv(keys, vals)


def sort_tiles_sample(
    keys,
    vals: jax.Array,
    *,
    num_samples: int,
    impl: str | None = None,
    interpret: bool | None = None,
    block_rows: int | None = None,
    strategy: str = "bitonic",
    radix_bits: int = 4,
    merge_run: int = 512,
):
    """Fused Steps 2+3: sorted (m, T) tiles plus the s equidistant
    per-tile samples, from one read of the tiles.

    Args:
        As :func:`sort_tiles` (including ``strategy``), plus
        ``num_samples`` (must divide T).
    Returns:
        (sorted_keys, sorted_vals, sample_keys (m, s), sample_vals) —
        keys in the input structure.
    """
    impl = impl or default_impl()
    _check_strategy(strategy)
    faults.check("kernel.launch")  # trace-time chaos site (DESIGN.md §11)
    if impl == "pallas":
        interpret = default_interpret() if interpret is None else interpret
        if strategy == "radix":
            return _radix.sort_tiles_sample_kv(
                keys, vals, num_samples=num_samples, radix_bits=radix_bits,
                block_rows=block_rows, interpret=interpret,
            )
        if strategy == "merge":
            return _merge.sort_tiles_sample_kv(
                keys, vals, num_samples=num_samples, merge_run=merge_run,
                block_rows=block_rows, interpret=interpret,
            )
        return _bitonic.sort_tiles_sample_kv(
            keys,
            vals,
            num_samples=num_samples,
            block_rows=block_rows,
            interpret=interpret,
        )
    if strategy == "radix":
        return _radix.composite_sort_sample_rows(
            keys, vals, num_samples=num_samples
        )
    if strategy == "merge":
        return _merge.hybrid_sort_sample_rows(
            keys, vals, num_samples=num_samples, merge_run=merge_run
        )
    return _ref.sort_tiles_sample_kv(keys, vals, num_samples=num_samples)


def splitter_ranks(
    keys, vals, sp_keys, sp_vals, *, impl: str | None = None,
    interpret: bool | None = None,
):
    """(m, S) rank of each splitter in each tile (canonical keys).

    Args:
        keys/vals: (m, T) canonical key words + int32 payloads.
        sp_keys/sp_vals: (m, S) per-tile splitters, same key structure.
        impl/interpret: as :func:`sort_tiles`.
    Returns:
        (m, S) int32 ranks (see kernels.splitter.splitter_ranks).
    """
    impl = impl or default_impl()
    if impl == "pallas":
        interpret = default_interpret() if interpret is None else interpret
        return _splitter.splitter_ranks(
            keys, vals, sp_keys, sp_vals, interpret=interpret
        )
    return _ref.splitter_ranks(keys, vals, sp_keys, sp_vals)


def splitter_partition(
    keys, vals, sp_keys, sp_vals, *, impl: str | None = None,
    interpret: bool | None = None, block_rows: int | None = None,
):
    """Fused Steps 6+7 epilogue: (ranks (m, S), counts (m, S+1)) per tile
    from one read of the tiles (canonical keys, multi-word accepted).

    Args/Returns: as :func:`splitter_ranks`, plus bucket counts.
    """
    impl = impl or default_impl()
    if impl == "pallas":
        interpret = default_interpret() if interpret is None else interpret
        return _splitter.splitter_partition(
            keys, vals, sp_keys, sp_vals,
            block_rows=block_rows, interpret=interpret,
        )
    return _ref.splitter_partition(keys, vals, sp_keys, sp_vals)


def topk(
    x: jax.Array,
    k: int,
    *,
    impl: str | None = None,
    interpret: bool | None = None,
):
    """Row-wise top-k (descending) of (R, C) scores.

    Args:
        x: (R, C) scores in any supported key dtype (int/uint/float,
            8..64-bit, bool — see ``core/key_codec``).
        k: 1 <= k <= C.
        impl/interpret: as :func:`sort_tiles`.
    Returns:
        (values (R, k) in x.dtype, indices (R, k) int32); ties toward
        the smaller index, matching jax.lax.top_k.  Non-power-of-two C
        (real vocab sizes: 50257, 151936, ...) is padded up with
        worst-score columns, which can never enter the top-k since
        k <= C (pad columns lose index ties too).
    """
    impl = impl or default_impl()
    # Descending codec: ascending canonical order == descending score.
    codec = codec_for(x.dtype, descending=True)
    words = codec.encode(x)
    r, c = words[0].shape
    assert 1 <= k <= c, (k, c)
    cp = 1
    while cp < c:
        cp *= 2
    if cp > c:  # all-ones == the worst possible encoded score
        words = tuple(
            jnp.concatenate(
                [w, jnp.full((r, cp - c), jnp.uint32(0xFFFFFFFF))], axis=1
            )
            for w in words
        )
        c = cp
    if impl == "pallas":
        interpret = default_interpret() if interpret is None else interpret
        block_rows = _bitonic.largest_pow2_divisor(r, 256)
        tk, ti = _topk.topk_desc(
            words, k=k, block_rows=block_rows, interpret=interpret
        )
    else:
        tk, ti = _ref.topk_desc(words, k=k)
    return codec.decode(as_words(tk)), ti
