"""Pure-jnp oracles for every Pallas kernel (the ``ref.py`` contract).

These are the ground truth the kernels are validated against in tests
(interpret=True vs ref, swept over shapes/dtypes + hypothesis).  They are
also the implementation used on the ``impl="xla"`` path (dry-run compiles
with 512 host devices, where emulated Pallas would bloat the HLO).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def sort_tiles_kv(keys: jax.Array, vals: jax.Array):
    """Lexicographic (key, value) ascending sort of each row of (m, T)."""
    return jax.lax.sort((keys, vals), dimension=-1, num_keys=2)


def sort_tiles_sample_kv(keys: jax.Array, vals: jax.Array, *, num_samples: int):
    """Oracle for the fused sort+sample kernel: sorted rows plus the
    s equidistant samples (elements (j+1)*T/s - 1) of each sorted row."""
    m, t = keys.shape
    assert t % num_samples == 0, (t, num_samples)
    sk, sv = jax.lax.sort((keys, vals), dimension=-1, num_keys=2)
    chunk = t // num_samples
    samp_k = sk.reshape(m, num_samples, chunk)[:, :, -1]
    samp_v = sv.reshape(m, num_samples, chunk)[:, :, -1]
    return sk, sv, samp_k, samp_v


def splitter_ranks(keys, vals, sp_keys, sp_vals):
    """(m, S) ranks: # elements of tile i lexicographically < splitter (i, j).

    keys/vals: (m, T) tiles; sp_keys/sp_vals: (m, S) per-tile splitters.
    """
    lt = (keys[:, :, None] < sp_keys[:, None, :]) | (
        (keys[:, :, None] == sp_keys[:, None, :])
        & (vals[:, :, None] < sp_vals[:, None, :])
    )
    return jnp.sum(lt.astype(jnp.int32), axis=1)


def splitter_partition(keys, vals, sp_keys, sp_vals):
    """Oracle for the fused Step 6+7 epilogue: (ranks (m, S),
    counts (m, S+1)) where counts[i, j] = size of bucket j in tile i."""
    m, t = keys.shape
    ranks = splitter_ranks(keys, vals, sp_keys, sp_vals)
    starts = jnp.concatenate([jnp.zeros((m, 1), jnp.int32), ranks], axis=1)
    ends = jnp.concatenate([ranks, jnp.full((m, 1), t, jnp.int32)], axis=1)
    return ranks, ends - starts


def topk_desc(keys: jax.Array, *, k: int):
    """Row-wise smallest-k of canonical uint32 keys (== top-k scores).

    Matches kernels.topk.topk_desc: ties toward smaller column index.
    """
    r, c = keys.shape
    idx = jax.lax.broadcasted_iota(jnp.int32, (r, c), 1)
    sk, si = jax.lax.sort((keys, idx), dimension=-1, num_keys=2)
    return sk[:, :k], si[:, :k]
