"""Pure-jnp oracles for every Pallas kernel (the ``ref.py`` contract).

These are the ground truth the kernels are validated against in tests
(interpret=True vs ref, swept over shapes/dtypes + hypothesis).  They are
also the implementation used on the ``impl="xla"`` path (dry-run compiles
with 512 host devices, where emulated Pallas would bloat the HLO).

Like the kernels, every entry accepts keys as a bare uint32 array (the
one-word fast path) or a tuple of canonical uint32 word arrays (msw
first, see ``core/key_codec``); comparison is lexicographic on
``(*words, payload)`` via ``lax.sort(num_keys=len(words)+1)``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.bitonic import as_words, like_words


def sort_tiles_kv(keys, vals: jax.Array):
    """Lexicographic (*key_words, value) ascending sort of each row of (m, T).

    Args:
        keys: (m, T) uint32 word array or tuple of word arrays (msw first).
        vals: (m, T) int32 payloads.
    Returns:
        (sorted keys in the input structure, sorted vals).
    """
    words = as_words(keys)
    out = jax.lax.sort(
        (*words, vals), dimension=-1, num_keys=len(words) + 1
    )
    return like_words(tuple(out[:-1]), keys), out[-1]


def sort_tiles_sample_kv(keys, vals: jax.Array, *, num_samples: int):
    """Oracle for the fused sort+sample kernel: sorted rows plus the
    s equidistant samples (elements (j+1)*T/s - 1) of each sorted row.

    Returns:
        (sorted_keys, sorted_vals, sample_keys (m, s), sample_vals) —
        keys in the input structure.
    """
    words = as_words(keys)
    m, t = words[0].shape
    assert t % num_samples == 0, (t, num_samples)
    out = jax.lax.sort(
        (*words, vals), dimension=-1, num_keys=len(words) + 1
    )
    chunk = t // num_samples
    samples = tuple(
        a.reshape(m, num_samples, chunk)[:, :, -1] for a in out
    )
    return (
        like_words(tuple(out[:-1]), keys),
        out[-1],
        like_words(tuple(samples[:-1]), keys),
        samples[-1],
    )


def splitter_ranks(keys, vals, sp_keys, sp_vals):
    """(m, S) ranks: # elements of tile i lexicographically < splitter (i, j).

    Args:
        keys/vals: (m, T) tiles; sp_keys/sp_vals: (m, S) per-tile
        splitters — keys in either key structure (must match).
    """
    words = as_words(keys)
    sp_words = as_words(sp_keys)
    parts = words + (vals,)
    sp_parts = sp_words + (sp_vals,)
    lt = parts[0][:, :, None] < sp_parts[0][:, None, :]
    eq = parts[0][:, :, None] == sp_parts[0][:, None, :]
    for a, b in zip(parts[1:], sp_parts[1:]):
        lt = lt | (eq & (a[:, :, None] < b[:, None, :]))
        eq = eq & (a[:, :, None] == b[:, None, :])
    return jnp.sum(lt, axis=1, dtype=jnp.int32)


def splitter_partition(keys, vals, sp_keys, sp_vals):
    """Oracle for the fused Step 6+7 epilogue: (ranks (m, S),
    counts (m, S+1)) where counts[i, j] = size of bucket j in tile i."""
    m, t = as_words(keys)[0].shape
    ranks = splitter_ranks(keys, vals, sp_keys, sp_vals)
    starts = jnp.concatenate([jnp.zeros((m, 1), jnp.int32), ranks], axis=1)
    ends = jnp.concatenate([ranks, jnp.full((m, 1), t, jnp.int32)], axis=1)
    return ranks, ends - starts


def topk_desc(keys, *, k: int):
    """Row-wise smallest-k of canonical keys (== top-k scores).

    Matches kernels.topk.topk_desc: ties toward smaller column index.

    Args:
        keys: (R, C) uint32 word array or tuple of word arrays.
    Returns:
        (top_keys (R, k) in the input structure, top_idx (R, k) int32).
    """
    words = as_words(keys)
    r, c = words[0].shape
    idx = jax.lax.broadcasted_iota(jnp.int32, (r, c), 1)
    out = jax.lax.sort(
        (*words, idx), dimension=-1, num_keys=len(words) + 1
    )
    return (
        like_words(tuple(a[:, :k] for a in out[:-1]), keys),
        out[-1][:, :k],
    )
