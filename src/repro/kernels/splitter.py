"""Pallas TPU kernel: splitter ranks per sorted tile (Step 6, Sample Indexing).

The paper locates the s global samples in each sorted sublist with log(s)
rounds of parallel binary search, carefully staggered to avoid *shared-
memory bank conflicts* on 2010-era GPUs.  TPU VMEM has no bank conflicts
and the VPU is 8x128 wide, so the TPU-idiomatic equivalent is a single
broadcast compare-and-reduce: for every splitter j, its rank in the tile
is  sum_i [ (k_i, v_i) < (sk_j, sv_j) ]  — one (T x S) comparison matrix
reduced over T.  This is branch-free, needs no serialization, and the
matrix (T*S bytes of i8 predicate) fits comfortably in VMEM for
T <= 16K, S <= 256.

Two entry points (see DESIGN.md §3):
  * ``splitter_ranks`` — the standalone Step-6 kernel, kept as the
    reference path (ranks only).
  * ``splitter_partition`` — the FUSED epilogue used by the hot path:
    one read of the tiles produces both the ranks AND the per-tile
    bucket counts (Step 7's input), so the count derivation never
    touches HBM again.  It is also row-blocked: one grid program
    partitions ``block_rows`` tiles.

Comparison is lexicographic on ``(*key_words, value)`` to match the sort
kernel: keys are one or more canonical uint32 word arrays (msw first —
see ``core/key_codec``), each extra word adds one cmp+select level to
the comparison matrix.  Both entries accept a bare uint32 array (the
one-word fast path) or a tuple of word arrays.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.bitonic import as_words, largest_pow2_divisor


def _lt_matrix(words, vals, sp_words, sp_vals):
    """(..., T, S) lexicographic (*words, val) < (*sp_words, sp_val).

    words/sp_words: parallel tuples of uint32 word arrays (msw first),
    shapes (..., T) and (..., S); vals/sp_vals: int32 payloads.
    """
    parts = words + (vals,)
    sp_parts = sp_words + (sp_vals,)
    lt = parts[0][..., :, None] < sp_parts[0][..., None, :]
    eq = parts[0][..., :, None] == sp_parts[0][..., None, :]
    for a, b in zip(parts[1:], sp_parts[1:]):
        lt = lt | (eq & (a[..., :, None] < b[..., None, :]))
        eq = eq & (a[..., :, None] == b[..., None, :])
    return lt


def _splitter_kernel(*refs, num_words: int):
    nw1 = num_words + 1
    words = tuple(r[0, :] for r in refs[:num_words])  # (T,) each
    vals = refs[num_words][0, :]
    sp_words = tuple(r[0, :] for r in refs[nw1:nw1 + num_words])  # (S,)
    sp_vals = refs[nw1 + num_words][0, :]
    out_ref = refs[-1]
    lt = _lt_matrix(words, vals, sp_words, sp_vals)
    out_ref[0, :] = jnp.sum(lt, axis=0, dtype=jnp.int32)


@functools.partial(jax.jit, static_argnames=("interpret",))
def splitter_ranks(
    keys,
    vals: jax.Array,
    sp_keys,
    sp_vals: jax.Array,
    *,
    interpret: bool = True,
):
    """Rank of each splitter in each (sorted or unsorted) tile.

    Args:
        keys: (m, T) uint32 canonical key words (bare array or tuple,
            msw first); vals: (m, T) int32 payloads.
        sp_keys/sp_vals: (m, S) per-tile splitters in the same key
            structure as ``keys``.
    Returns:
        (m, S) int32: ranks[i, j] = #elements of tile i strictly less
        (lexicographically) than splitter (i, j).  Monotone in j when
        splitters are sorted; the tile itself need not be sorted for
        correctness (counting, not searching) — sortedness only matters
        for the relocation step.
    """
    words = as_words(keys)
    sp_words = as_words(sp_keys)
    nw = len(words)
    assert len(sp_words) == nw
    m, t = words[0].shape
    s = sp_words[0].shape[1]
    assert all(w.shape == (m, t) and w.dtype == jnp.uint32 for w in words)
    assert all(w.shape == (m, s) and w.dtype == jnp.uint32 for w in sp_words)
    assert vals.dtype == jnp.int32 and sp_vals.dtype == jnp.int32
    grid = (m,)
    tile_spec = pl.BlockSpec((1, t), lambda i: (i, 0))
    sp_spec = pl.BlockSpec((1, s), lambda i: (i, 0))
    return pl.pallas_call(
        functools.partial(_splitter_kernel, num_words=nw),
        grid=grid,
        in_specs=[tile_spec] * (nw + 1) + [sp_spec] * (nw + 1),
        out_specs=pl.BlockSpec((1, s), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, s), jnp.int32),
        interpret=interpret,
    )(*words, vals, *sp_words, sp_vals)


def partition_block_rows(
    m: int, t: int, s: int, *, num_words: int = 1,
    block_rows: int | None = None,
) -> int:
    """Resolve the fused-partition kernel's tiles-per-grid-program.

    The single source of truth for the kernel's VMEM model, shared with
    the plan builder (``core/plan.py``) so plans carry the exact block
    geometry the kernel will run — idempotent: feeding a resolved value
    back returns it unchanged.

    Args:
        m: tile count; t: tile width; s: splitters per tile.
        num_words: uint32 key words per element.
        block_rows: optional upper bound (e.g. a plan-carried value).
    Returns:
        The largest power-of-two divisor of ``m`` whose per-program
        comparison matrix + tile buffers fit a 4 MiB VMEM budget.
    """
    # (T x S) i32 comparison matrix per row dominates VMEM here (one
    # lt+eq predicate pair per key word adds to it).
    per_row = 4 * t * (s + 2) * (num_words + 1) // 2 + 4 * t * (num_words + 1)
    limit = max((4 * 1024 * 1024) // per_row, 1)
    if block_rows is not None:
        limit = min(limit, block_rows)
    return largest_pow2_divisor(m, limit)


def _partition_kernel(*refs, num_words: int):
    nw1 = num_words + 1
    words = tuple(r[...] for r in refs[:num_words])  # (block_rows, T)
    vals = refs[num_words][...]
    sp_words = tuple(r[...] for r in refs[nw1:nw1 + num_words])
    sp_vals = refs[nw1 + num_words][...]
    ranks_ref, counts_ref = refs[-2], refs[-1]
    t = vals.shape[1]
    lt = _lt_matrix(words, vals, sp_words, sp_vals)  # (block_rows, T, S)
    ranks = jnp.sum(lt, axis=1, dtype=jnp.int32)  # (block_rows, S)
    ranks_ref[...] = ranks
    # Bucket j of a sorted tile is [start_j, end_j) with start_0 = 0,
    # start_j = ranks[j-1], end_{S} = T: counts = ends - starts, computed
    # here so Step 7 never re-reads the tiles.
    starts = jnp.concatenate([jnp.zeros_like(ranks[:, :1]), ranks], axis=1)
    ends = jnp.concatenate([ranks, jnp.full_like(ranks[:, :1], t)], axis=1)
    counts_ref[...] = ends - starts


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def splitter_partition(
    keys,
    vals: jax.Array,
    sp_keys,
    sp_vals: jax.Array,
    *,
    block_rows: int | None = None,
    interpret: bool = True,
):
    """Fused Step 6+7 epilogue: splitter ranks AND bucket counts per tile.

    Args:
        Same as :func:`splitter_ranks` (multi-word keys accepted), plus
        ``block_rows`` tiles partitioned per grid program (None = auto;
        clamped to a power-of-two divisor of m).
    Returns:
        ranks  (m, S)   int32 — rank of splitter j in tile i, and
        counts (m, S+1) int32 — size of bucket j in tile i (sums to T),
        from a single HBM read of the tiles.
    """
    words = as_words(keys)
    sp_words = as_words(sp_keys)
    nw = len(words)
    assert len(sp_words) == nw
    m, t = words[0].shape
    s = sp_words[0].shape[1]
    assert all(w.shape == (m, t) and w.dtype == jnp.uint32 for w in words)
    assert all(w.shape == (m, s) and w.dtype == jnp.uint32 for w in sp_words)
    assert vals.dtype == jnp.int32 and sp_vals.dtype == jnp.int32
    block_rows = partition_block_rows(
        m, t, s, num_words=nw, block_rows=block_rows
    )
    grid = (m // block_rows,)
    tile_spec = pl.BlockSpec((block_rows, t), lambda i: (i, 0))
    sp_spec = pl.BlockSpec((block_rows, s), lambda i: (i, 0))
    return pl.pallas_call(
        functools.partial(_partition_kernel, num_words=nw),
        grid=grid,
        in_specs=[tile_spec] * (nw + 1) + [sp_spec] * (nw + 1),
        out_specs=[
            pl.BlockSpec((block_rows, s), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, s + 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, s), jnp.int32),
            jax.ShapeDtypeStruct((m, s + 1), jnp.int32),
        ],
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel",)
        ),
        interpret=interpret,
    )(*words, vals, *sp_words, sp_vals)
