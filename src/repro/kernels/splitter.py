"""Pallas TPU kernel: splitter ranks per sorted tile (Step 6, Sample Indexing).

The paper locates the s global samples in each sorted sublist with log(s)
rounds of parallel binary search, carefully staggered to avoid *shared-
memory bank conflicts* on 2010-era GPUs.  TPU VMEM has no bank conflicts
and the VPU is 8x128 wide, so the TPU-idiomatic equivalent is a single
broadcast compare-and-reduce: for every splitter j, its rank in the tile
is  sum_i [ (k_i, v_i) < (sk_j, sv_j) ]  — one (T x S) comparison matrix
reduced over T.  This is branch-free, needs no serialization, and the
matrix (T*S bytes of i8 predicate) fits comfortably in VMEM for
T <= 16K, S <= 256.

Comparison is lexicographic on (key, value) to match the sort kernel.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _splitter_kernel(k_ref, v_ref, sk_ref, sv_ref, out_ref):
    keys = k_ref[0, :]  # (T,)
    vals = v_ref[0, :]
    sk = sk_ref[0, :]  # (S,)
    sv = sv_ref[0, :]
    lt = (keys[:, None] < sk[None, :]) | (
        (keys[:, None] == sk[None, :]) & (vals[:, None] < sv[None, :])
    )
    out_ref[0, :] = jnp.sum(lt.astype(jnp.int32), axis=0)


@functools.partial(jax.jit, static_argnames=("interpret",))
def splitter_ranks(
    keys: jax.Array,
    vals: jax.Array,
    sp_keys: jax.Array,
    sp_vals: jax.Array,
    *,
    interpret: bool = True,
):
    """Rank of each splitter in each (sorted or unsorted) tile.

    keys/vals: (m, T) uint32/int32 tiles.
    sp_keys/sp_vals: (m, S) per-tile splitters (canonical uint32 / int32).
    Returns (m, S) int32: ranks[i, j] = #elements of tile i strictly less
    (lexicographically) than splitter (i, j).  Monotone in j when splitters
    are sorted; the tile itself need not be sorted for correctness (counting,
    not searching) — sortedness only matters for the relocation step.
    """
    m, t = keys.shape
    s = sp_keys.shape[1]
    assert sp_keys.shape == (m, s) and sp_vals.shape == (m, s)
    assert keys.dtype == jnp.uint32 and vals.dtype == jnp.int32
    assert sp_keys.dtype == jnp.uint32 and sp_vals.dtype == jnp.int32
    grid = (m,)
    tile_spec = pl.BlockSpec((1, t), lambda i: (i, 0))
    sp_spec = pl.BlockSpec((1, s), lambda i: (i, 0))
    return pl.pallas_call(
        _splitter_kernel,
        grid=grid,
        in_specs=[tile_spec, tile_spec, sp_spec, sp_spec],
        out_specs=pl.BlockSpec((1, s), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, s), jnp.int32),
        interpret=interpret,
    )(keys, vals, sp_keys, sp_vals)
