"""Pallas TPU kernel: splitter ranks per sorted tile (Step 6, Sample Indexing).

The paper locates the s global samples in each sorted sublist with log(s)
rounds of parallel binary search, carefully staggered to avoid *shared-
memory bank conflicts* on 2010-era GPUs.  TPU VMEM has no bank conflicts
and the VPU is 8x128 wide, so the TPU-idiomatic equivalent is a single
broadcast compare-and-reduce: for every splitter j, its rank in the tile
is  sum_i [ (k_i, v_i) < (sk_j, sv_j) ]  — one (T x S) comparison matrix
reduced over T.  This is branch-free, needs no serialization, and the
matrix (T*S bytes of i8 predicate) fits comfortably in VMEM for
T <= 16K, S <= 256.

Two entry points (see DESIGN.md §3):
  * ``splitter_ranks`` — the standalone Step-6 kernel, kept as the
    reference path (ranks only).
  * ``splitter_partition`` — the FUSED epilogue used by the hot path:
    one read of the tiles produces both the ranks AND the per-tile
    bucket counts (Step 7's input), so the count derivation never
    touches HBM again.  It is also row-blocked: one grid program
    partitions ``block_rows`` tiles.

Comparison is lexicographic on (key, value) to match the sort kernel.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.bitonic import largest_pow2_divisor


def _lt_matrix(keys, vals, sk, sv):
    """(..., T, S) lexicographic (key, val) < (splitter key, splitter val)."""
    return (keys[..., :, None] < sk[..., None, :]) | (
        (keys[..., :, None] == sk[..., None, :])
        & (vals[..., :, None] < sv[..., None, :])
    )


def _splitter_kernel(k_ref, v_ref, sk_ref, sv_ref, out_ref):
    keys = k_ref[0, :]  # (T,)
    vals = v_ref[0, :]
    sk = sk_ref[0, :]  # (S,)
    sv = sv_ref[0, :]
    lt = _lt_matrix(keys, vals, sk, sv)
    out_ref[0, :] = jnp.sum(lt.astype(jnp.int32), axis=0)


@functools.partial(jax.jit, static_argnames=("interpret",))
def splitter_ranks(
    keys: jax.Array,
    vals: jax.Array,
    sp_keys: jax.Array,
    sp_vals: jax.Array,
    *,
    interpret: bool = True,
):
    """Rank of each splitter in each (sorted or unsorted) tile.

    keys/vals: (m, T) uint32/int32 tiles.
    sp_keys/sp_vals: (m, S) per-tile splitters (canonical uint32 / int32).
    Returns (m, S) int32: ranks[i, j] = #elements of tile i strictly less
    (lexicographically) than splitter (i, j).  Monotone in j when splitters
    are sorted; the tile itself need not be sorted for correctness (counting,
    not searching) — sortedness only matters for the relocation step.
    """
    m, t = keys.shape
    s = sp_keys.shape[1]
    assert sp_keys.shape == (m, s) and sp_vals.shape == (m, s)
    assert keys.dtype == jnp.uint32 and vals.dtype == jnp.int32
    assert sp_keys.dtype == jnp.uint32 and sp_vals.dtype == jnp.int32
    grid = (m,)
    tile_spec = pl.BlockSpec((1, t), lambda i: (i, 0))
    sp_spec = pl.BlockSpec((1, s), lambda i: (i, 0))
    return pl.pallas_call(
        _splitter_kernel,
        grid=grid,
        in_specs=[tile_spec, tile_spec, sp_spec, sp_spec],
        out_specs=pl.BlockSpec((1, s), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, s), jnp.int32),
        interpret=interpret,
    )(keys, vals, sp_keys, sp_vals)


def _partition_kernel(k_ref, v_ref, sk_ref, sv_ref, ranks_ref, counts_ref):
    keys = k_ref[...]  # (block_rows, T)
    vals = v_ref[...]
    sk = sk_ref[...]  # (block_rows, S)
    sv = sv_ref[...]
    t = keys.shape[1]
    lt = _lt_matrix(keys, vals, sk, sv)  # (block_rows, T, S)
    ranks = jnp.sum(lt.astype(jnp.int32), axis=1)  # (block_rows, S)
    ranks_ref[...] = ranks
    # Bucket j of a sorted tile is [start_j, end_j) with start_0 = 0,
    # start_j = ranks[j-1], end_{S} = T: counts = ends - starts, computed
    # here so Step 7 never re-reads the tiles.
    starts = jnp.concatenate([jnp.zeros_like(ranks[:, :1]), ranks], axis=1)
    ends = jnp.concatenate([ranks, jnp.full_like(ranks[:, :1], t)], axis=1)
    counts_ref[...] = ends - starts


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def splitter_partition(
    keys: jax.Array,
    vals: jax.Array,
    sp_keys: jax.Array,
    sp_vals: jax.Array,
    *,
    block_rows: int | None = None,
    interpret: bool = True,
):
    """Fused Step 6+7 epilogue: splitter ranks AND bucket counts per tile.

    Same inputs as :func:`splitter_ranks`.  Returns
      ranks  (m, S)   int32 — rank of splitter j in tile i, and
      counts (m, S+1) int32 — size of bucket j in tile i (sums to T),
    from a single HBM read of the tiles.  ``block_rows`` tiles are
    partitioned per grid program (None = auto; must divide m).
    """
    m, t = keys.shape
    s = sp_keys.shape[1]
    assert sp_keys.shape == (m, s) and sp_vals.shape == (m, s)
    assert keys.dtype == jnp.uint32 and vals.dtype == jnp.int32
    assert sp_keys.dtype == jnp.uint32 and sp_vals.dtype == jnp.int32
    # (T x S) i32 comparison matrix per row dominates VMEM here.
    per_row = 4 * t * (s + 2)
    limit = max((4 * 1024 * 1024) // per_row, 1)
    if block_rows is not None:
        limit = min(limit, block_rows)
    block_rows = largest_pow2_divisor(m, limit)
    grid = (m // block_rows,)
    tile_spec = pl.BlockSpec((block_rows, t), lambda i: (i, 0))
    sp_spec = pl.BlockSpec((block_rows, s), lambda i: (i, 0))
    return pl.pallas_call(
        _partition_kernel,
        grid=grid,
        in_specs=[tile_spec, tile_spec, sp_spec, sp_spec],
        out_specs=[
            pl.BlockSpec((block_rows, s), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, s + 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, s), jnp.int32),
            jax.ShapeDtypeStruct((m, s + 1), jnp.int32),
        ],
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel",)
        ),
        interpret=interpret,
    )(keys, vals, sp_keys, sp_vals)
