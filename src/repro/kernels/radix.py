"""LSD radix-rank local sort over canonical uint32 key words.

The "radix" entry of the hybrid strategy dispatch (DESIGN.md §8): the
GPU sorting surveys (arXiv 1709.02520; arXiv 1511.03404) show radix
ranking dominating comparison networks on narrow integer keys, and the
key-codec layer (DESIGN.md §6) reduces EVERY dtype to canonical uint32
word tuples — so one radix formulation covers them all.  Multi-word
keys are handled word by word from the LEAST significant word: each
full word is consumed in ``32 / radix_bits`` stable digit passes, and
LSD stability makes the composition lexicographic over the words.

STRATEGY CONTRACT (shared with kernels/merge.py): this is a STABLE sort
keyed on the key words ONLY — the int32 payload rides along but does
not participate in comparisons.  Inside the pipeline that is exactly
equivalent to the bitonic path's lexicographic ``(*words, payload)``
order, because the executor maintains the invariant that equal-key
elements always arrive in increasing-payload order (entry payloads are
per-row ``arange``; relocation, sampling, padding and compaction all
preserve relative order of equal keys).  Callers outside the pipeline
must pass payloads that respect that invariant (e.g. ``arange`` rows).

Digit ranking is SCATTER-FREE (the DESIGN.md §4 rule): a pass never
builds a destination scatter.  Per (block_rows, T) block it computes,
for every DESTINATION slot, the source element that lands there:

  1. pack per-segment digit counts into uint32 counters (C = 8 elements
     per segment, one 4-bit field per digit, ``ceil(D/8)`` counter
     words) and Hillis-Steele-scan them WITHIN each segment — 4-bit
     fields cannot overflow since a segment holds 8 elements;
  2. unpack segment totals to (rows, S, D) counts and scan across the
     S segments, giving every (segment, digit) an inclusive prefix;
  3. per destination slot: find its digit (compare against the D
     exclusive digit starts), then its source segment (binary search of
     the inclusive segment prefixes — ``ceil(log2(S+1))`` steps), then
     its source element within the segment (binary search of the packed
     intra-segment prefix fields — ``ceil(log2(C))`` steps), and gather.

The same pure-jnp formulation is the Pallas kernel body (via
``bitonic.tile_sort_call``) and is directly differential-testable.  On
the xla path a documented STAND-IN is used instead (the same precedent
as the bitonic path's ``lax.sort`` oracle, kernels/ref.py): each digit
pass sorts the composite key ``(digit << log2(T)) | position`` with a
single-key ``lax.sort`` — stable by construction, and measured ~2.5x
faster than the two-key oracle on CPU at (256, 4096) tiles.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.bitonic import as_words, like_words, tile_sort_call

# Elements per scan segment: one packed uint32 holds 8 x 4-bit digit
# counters, and a segment of 8 elements can never overflow a field.
_SEG = 8


def _hillis(x, n: int, axis: int = -1):
    """Inclusive Hillis-Steele prefix sum of length-n axis (log2(n)
    shifted adds — branch-free, no gathers)."""
    k = 1
    while k < n:
        pad = jnp.zeros_like(jax.lax.slice_in_dim(x, 0, k, axis=axis))
        shifted = jnp.concatenate(
            [pad, jax.lax.slice_in_dim(x, 0, x.shape[axis] - k, axis=axis)],
            axis=axis,
        )
        x = x + shifted
        k *= 2
    return x


def digit_rank(d: jax.Array, num_digits: int) -> jax.Array:
    """Source permutation of one stable counting pass.

    Args:
        d: (rows, T) int32 digits in [0, num_digits); T a power of two.
        num_digits: D <= 16 (so D 4-bit counters fit two uint32 words).
    Returns:
        (rows, T) int32 ``src`` with ``take(x, src)`` = x stably sorted
        by digit (equal digits keep their order).
    """
    rows, t = d.shape
    assert t & (t - 1) == 0, t
    assert 2 <= num_digits <= 16, num_digits
    if t == 1:
        return jnp.zeros((rows, 1), jnp.int32)
    c = min(_SEG, t)
    s = t // c
    n_arr = (num_digits + _SEG - 1) // _SEG  # packed counter words

    # 1. packed per-segment counters + intra-segment inclusive scan.
    fld = ((d & (_SEG - 1)) << 2).astype(jnp.uint32)
    enc = jnp.uint32(1) << fld
    arr_id = d >> 3
    pres = [
        _hillis(
            jnp.where(arr_id == a, enc, jnp.uint32(0)).reshape(rows, s, c), c
        )
        for a in range(n_arr)
    ]  # (rows, S, C) each
    sh4 = (jnp.arange(_SEG, dtype=jnp.uint32) << 2)[None, None, :]

    # 2. unpack segment totals -> (rows, S, D) counts, scan across segments.
    cnt = jnp.concatenate(
        [((p[:, :, -1:] >> sh4) & 15).astype(jnp.int32) for p in pres],
        axis=2,
    )[:, :, :num_digits]
    inc_seg = _hillis(cnt, s, axis=1)  # (rows, S, D) inclusive over segments
    tot = inc_seg[:, -1, :]  # (rows, D)
    start = jnp.cumsum(tot, axis=1) - tot  # (rows, D) exclusive digit starts

    # 3a. digit of each destination slot: last k with start[k] <= p.
    # D compares instead of a searchsorted gather (kernel-friendly).
    p = jax.lax.broadcasted_iota(jnp.int32, (rows, t), 1)
    j = -jnp.ones((rows, t), jnp.int32)
    for k in range(num_digits):
        j = j + (start[:, k:k + 1] <= p).astype(jnp.int32)
    q = p - jnp.take_along_axis(start, j, axis=1)

    # 3b. source segment: first seg with inclusive count > q.  The
    # unknown interval [lo, hi) over [0, S] needs ceil(log2(S+1)) =
    # S.bit_length() halvings; the answer is always < S (q < tot), so
    # mid stays in bounds throughout.
    flat = inc_seg.reshape(rows, s * num_digits)
    lo = jnp.zeros((rows, t), jnp.int32)
    hi = jnp.full((rows, t), s, jnp.int32)
    for _ in range(s.bit_length()):
        mid = (lo + hi) >> 1
        cmid = jnp.take_along_axis(flat, mid * num_digits + j, axis=1)
        gt = cmid > q
        hi = jnp.where(gt, mid, hi)
        lo = jnp.where(gt, lo, mid + 1)
    seg = lo
    excl = jnp.where(
        seg > 0,
        jnp.take_along_axis(
            flat, jnp.maximum(seg - 1, 0) * num_digits + j, axis=1
        ),
        0,
    )
    qs = q - excl  # rank within the source segment

    # 3c. source element within the segment: first c with packed
    # intra-segment prefix field > qs (inclusive-range search with an
    # update mask, ceil(log2(C)) steps).
    if c == 1:
        return seg
    pcat = jnp.concatenate([pr.reshape(rows, t) for pr in pres], axis=1)
    fldj = ((j & (_SEG - 1)) << 2).astype(jnp.uint32)
    base = (j >> 3) * t + seg * c
    lo2 = jnp.zeros((rows, t), jnp.int32)
    hi2 = jnp.full((rows, t), c - 1, jnp.int32)
    for _ in range((c - 1).bit_length()):
        mid = (lo2 + hi2) >> 1
        pv = jnp.take_along_axis(pcat, base + mid, axis=1)
        cmid = ((pv >> fldj) & jnp.uint32(15)).astype(jnp.int32)
        gt = cmid > qs
        upd = lo2 < hi2
        hi2 = jnp.where(upd & gt, mid, hi2)
        lo2 = jnp.where(upd & ~gt, mid + 1, lo2)
    return seg * c + lo2


def radix_sort_rows(keys, vals: jax.Array, *, radix_bits: int = 4):
    """Stable LSD radix sort of each row of (rows, T) by the key words.

    The shared strategy formulation: the Pallas kernel body AND the
    reference implementation.  ``32 / radix_bits`` digit passes per
    word, least-significant word first; each pass is a scatter-free
    rank (:func:`digit_rank`) + one gather per array.

    Args:
        keys: (rows, T) uint32 word array or tuple (msw first).
        vals: (rows, T) int32 payloads (carried, NOT compared — see the
            strategy contract in the module docstring).
        radix_bits: digit width in {1, 2, 4}.
    Returns:
        (sorted keys in the input structure, payloads moved alongside).
    """
    assert radix_bits in (1, 2, 4), radix_bits
    words = as_words(keys)
    rows, t = words[0].shape
    if t == 1:
        return like_words(words, keys), vals
    num_digits = 1 << radix_bits
    parts = list(words) + [vals]
    for wi in reversed(range(len(words))):  # least significant word first
        for sh in range(0, 32, radix_bits):
            d = (
                (parts[wi] >> jnp.uint32(sh)) & jnp.uint32(num_digits - 1)
            ).astype(jnp.int32)
            src = digit_rank(d, max(num_digits, 2))
            parts = [jnp.take_along_axis(x, src, axis=1) for x in parts]
    return like_words(tuple(parts[:-1]), keys), parts[-1]


# ----------------------------------------------------------------------
# Pallas entry points (mirror kernels/bitonic.py)
# ----------------------------------------------------------------------


@functools.partial(
    jax.jit, static_argnames=("radix_bits", "block_rows", "interpret")
)
def sort_tiles_kv(
    keys,
    vals: jax.Array,
    *,
    radix_bits: int = 4,
    block_rows: int | None = None,
    interpret: bool = True,
):
    """Row-blocked Pallas radix sort of (m, T) tiles (strategy="radix").

    Args/Returns: as ``bitonic.sort_tiles_kv``, but rows are sorted by
    the radix rank-gather passes (stable, key words only — see the
    strategy contract above).
    """
    words = as_words(keys)
    out = tile_sort_call(
        words, vals, 0, block_rows, interpret,
        sort_rows=functools.partial(radix_sort_rows, radix_bits=radix_bits),
    )
    return like_words(tuple(out[:-1]), keys), out[-1]


@functools.partial(
    jax.jit,
    static_argnames=("num_samples", "radix_bits", "block_rows", "interpret"),
)
def sort_tiles_sample_kv(
    keys,
    vals: jax.Array,
    *,
    num_samples: int,
    radix_bits: int = 4,
    block_rows: int | None = None,
    interpret: bool = True,
):
    """Radix tile sort with the Step-3 sample epilogue fused in
    (same layout contract as ``bitonic.sort_tiles_sample_kv``)."""
    words = as_words(keys)
    nw = len(words)
    out = tile_sort_call(
        words, vals, num_samples, block_rows, interpret,
        sort_rows=functools.partial(radix_sort_rows, radix_bits=radix_bits),
    )
    return (
        like_words(tuple(out[:nw]), keys),
        out[nw],
        like_words(tuple(out[nw + 1:2 * nw + 1]), keys),
        out[2 * nw + 1],
    )


# ----------------------------------------------------------------------
# xla stand-in: composite-key single-key lax.sort passes
# ----------------------------------------------------------------------


def composite_sort_rows(keys, vals: jax.Array):
    """Stable LSD radix sort via composite single-key ``lax.sort`` passes
    — the documented xla STAND-IN for the radix strategy (the same
    proxy pattern as ref.py for bitonic; see the module docstring).

    Each pass sorts ``(digit << log2(T)) | position`` as ONE uint32 key:
    the position bits make the pass stable and directly encode the
    source permutation, which is composed across passes and applied
    once at the end.  Digit width is ``min(16, 32 - log2(T))`` bits, so
    a 32-bit word costs 2 passes for tiles up to 2^16.
    """
    words = as_words(keys)
    rows, t = words[0].shape
    if t == 1:
        return like_words(words, keys), vals
    assert t & (t - 1) == 0, t
    pb = (t - 1).bit_length()  # log2(T) position bits
    db = min(16, 32 - pb)
    assert db >= 1, f"tile width {t} too large for composite radix"
    pos = jax.lax.broadcasted_iota(jnp.uint32, (rows, t), 1)
    mask_pos = jnp.uint32(t - 1)
    src_total = jax.lax.broadcasted_iota(jnp.int32, (rows, t), 1)
    for wi in reversed(range(len(words))):  # least significant word first
        w = words[wi]
        for sh in range(0, 32, db):
            bits = min(db, 32 - sh)
            cur = jnp.take_along_axis(w, src_total, axis=1)
            d = (cur >> jnp.uint32(sh)) & jnp.uint32((1 << bits) - 1)
            comp = (d << jnp.uint32(pb)) | pos
            comp = jax.lax.sort(comp, dimension=1)
            src = (comp & mask_pos).astype(jnp.int32)
            src_total = jnp.take_along_axis(src_total, src, axis=1)
    out_words = tuple(
        jnp.take_along_axis(w, src_total, axis=1) for w in words
    )
    return (
        like_words(out_words, keys),
        jnp.take_along_axis(vals, src_total, axis=1),
    )


def composite_sort_sample_rows(keys, vals: jax.Array, *, num_samples: int):
    """Stand-in for the fused sort+sample entry: composite radix sort,
    then the s equidistant samples by reshape + slice (as ref.py)."""
    sk, sv = composite_sort_rows(keys, vals)
    words = as_words(sk)
    m, t = words[0].shape
    assert t % num_samples == 0, (t, num_samples)
    chunk = t // num_samples
    samples = tuple(
        a.reshape(m, num_samples, chunk)[:, :, -1] for a in words + (sv,)
    )
    return sk, sv, like_words(tuple(samples[:-1]), keys), samples[-1]
