"""Pallas TPU kernel: row-blocked bitonic (key, value) sort of VMEM tiles.

This is the TPU adaptation of Steps 2/4/9 of GPU BUCKET SORT (Dehne &
Zaboli 2010).  The paper sorts 2K-item sublists per SM in shared memory
with a bitonic network because it is branch-free and SIMD-perfect; the
same argument holds on the TPU VPU: every compare-exchange pass is a
reshape + vectorized min/max/select with *no* data-dependent control
flow, so the whole network lowers to straight-line vector code.

Layout notes (target = TPU v5e; see DESIGN.md §3):
  * One grid program sorts a ``(block_rows, T)`` BLOCK of tiles held in
    VMEM, running the compare-exchange network along the lane axis of
    all ``block_rows`` rows at once.  With ``block_rows >= 8`` every
    vector op is a dense (8-sublane x 128-lane) tile, instead of the
    1/8-occupancy (1, T) ops the per-tile formulation issues.
  * ``block_rows`` is auto-picked by :func:`auto_block_rows` to fill a
    VMEM budget; the grid axis is declared ``parallel`` (programs are
    independent) so Mosaic may pipeline/parallelize blocks freely.
  * ``T`` must be a power of two and a multiple of 128 (lane width)
    so the (nb, 2, d) reshapes stay lane-aligned for d >= 128.  Strides
    d < 128 become intra-lane shuffles; Mosaic handles them, and a
    production-tuned variant would switch to sublane rotates there —
    that is a lowering detail, not an algorithmic one.
  * Comparison is LEXICOGRAPHIC on ``(*key_words, value)``.  Keys are
    tuples of canonical uint32 words, most significant first (one word
    for <= 32-bit dtypes, two for 64-bit — see ``core/key_codec``); the
    caller passes the original element index as the value, which (a)
    makes every compared pair unique so the regular-sampling bucket
    bound ≤ 2n/s holds for any duplicate distribution, and (b) makes
    the sort STABLE.  The compare cost is one extra vector cmp+select
    chain per extra word (DESIGN.md §6), data movement scales with the
    word count.
  * Step 3 of the algorithm (equidistant sample extraction) is FUSED
    into the kernel as an optional epilogue output: the s per-tile
    samples are the last element of each T/s chunk of the sorted row,
    a pure reshape + slice while the block is still VMEM-resident.
    This removes one full HBM read of the sorted tiles (DESIGN.md §3).

Keys: one or more canonical uint32 word arrays; values: int32.  Every
public entry accepts either a bare ``(m, T)`` uint32 array (the one-word
fast path, bit-compatible with the pre-codec API) or a tuple of word
arrays, and returns keys in the same structure.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# VMEM budget for one grid program's block: in + out, key words + values
# (2*(num_words+1) buffers of block_rows * T * 4 bytes).  8 MiB of the
# ~16 MiB/core leaves headroom for the network's double-buffered
# temporaries.
_VMEM_BUDGET_BYTES = 8 * 1024 * 1024


def as_words(keys) -> tuple[jax.Array, ...]:
    """Normalize a key argument to a tuple of uint32 word arrays.

    Args:
        keys: a single uint32 array (one-word keys) or a tuple/list of
            uint32 word arrays, most significant first.
    Returns:
        Tuple of word arrays (length >= 1, all the same shape).
    """
    if isinstance(keys, (tuple, list)):
        assert len(keys) >= 1
        return tuple(keys)
    return (keys,)


def like_words(words: tuple[jax.Array, ...], keys):
    """Return ``words`` in the structure of the original ``keys`` arg:
    a bare array if the caller passed one, else a tuple."""
    if isinstance(keys, (tuple, list)):
        return tuple(words)
    assert len(words) == 1
    return words[0]


def lex_gt(lo_parts, hi_parts):
    """Elementwise lexicographic ``lo > hi`` over parallel word lists.

    lo_parts/hi_parts: equal-length sequences of arrays compared word by
    word, most significant first (the caller appends the payload as the
    final word).  Returns a bool array of the common shape.
    """
    gt = lo_parts[0] > hi_parts[0]
    eq = lo_parts[0] == hi_parts[0]
    for a, b in zip(lo_parts[1:], hi_parts[1:]):
        gt = gt | (eq & (a > b))
        eq = eq & (a == b)
    return gt


def _compare_exchange(parts, d: int, size: int):
    """One bitonic compare-exchange pass at stride ``d`` within ``size``
    blocks, applied jointly to every array in ``parts`` (key words +
    payload, 1-D, length T a power of two).  Element i is paired with
    i ^ d; direction is ascending iff (i & size) == 0.
    """
    t = parts[0].shape[0]
    nb = t // (2 * d)
    r3 = [p.reshape(nb, 2, d) for p in parts]
    # Global index of the low element of block b is 2*b*d (+ lane offset < d),
    # and d <= size/2, so bit log2(size) is decided purely by the block id.
    blk = jax.lax.broadcasted_iota(jnp.int32, (nb, 1), 0)
    asc = ((2 * blk * d) & size) == 0  # (nb, 1) bool

    los = [p[:, 0, :] for p in r3]
    his = [p[:, 1, :] for p in r3]
    gt = lex_gt(los, his)
    swap = jnp.where(asc, gt, ~gt)
    return tuple(
        jnp.stack(
            (jnp.where(swap, hi, lo), jnp.where(swap, lo, hi)), axis=1
        ).reshape(t)
        for lo, hi in zip(los, his)
    )


def bitonic_network(keys, vals):
    """Full bitonic sorting network on 1-D (keys, vals); T = power of two.

    Args:
        keys: uint32 word array (or tuple of word arrays, msw first).
        vals: int32 payload array, same length T (a power of two).
    Returns:
        (sorted keys in the input structure, sorted vals),
        lexicographically ascending on (*words, payload).

    Unrolled at trace time: log2(T)*(log2(T)+1)/2 vectorized passes.
    Kept as the 1-D reference formulation (and the per-tile baseline in
    ``benchmarks/step_breakdown.py``); the kernel path uses the row-
    blocked :func:`bitonic_network_rows`.
    """
    words = as_words(keys)
    t = words[0].shape[0]
    assert t & (t - 1) == 0, f"tile size {t} must be a power of two"
    parts = words + (vals,)
    size = 2
    while size <= t:
        d = size // 2
        while d >= 1:
            parts = _compare_exchange(parts, d, size)
            d //= 2
        size *= 2
    return like_words(parts[:-1], keys), parts[-1]


# --- Row-wise bitonic along the last axis: shared by the blocked tile-sort
# --- kernel, the top-k kernel, and the pure-jnp reference path.


def _row_compare_exchange(parts, d: int, size: int):
    """Compare-exchange along the LAST axis of (..., C) arrays, applied
    jointly to every array in ``parts`` (key words + payload)."""
    c = parts[0].shape[-1]
    lead = parts[0].shape[:-1]
    nb = c // (2 * d)
    r3 = [p.reshape(lead + (nb, 2, d)) for p in parts]
    blk = jax.lax.broadcasted_iota(jnp.int32, (nb, 1), 0)
    asc = ((2 * blk * d) & size) == 0  # (nb, 1), broadcasts over leading dims

    los = [p[..., 0, :] for p in r3]
    his = [p[..., 1, :] for p in r3]
    gt = lex_gt(los, his)
    swap = jnp.where(asc, gt, ~gt)
    return tuple(
        jnp.stack(
            (jnp.where(swap, hi, lo), jnp.where(swap, lo, hi)), axis=-2
        ).reshape(lead + (c,))
        for lo, hi in zip(los, his)
    )


def bitonic_network_rows(keys, vals):
    """Bitonic sort along the last axis of (..., C); C = power of two.

    Args:
        keys: uint32 word array (or tuple of word arrays, msw first),
            shape (..., C).
        vals: int32 payload, same shape.
    Returns:
        (sorted keys in the input structure, sorted vals): every row
        ascending in the lexicographic (*words, payload) order.
    """
    words = as_words(keys)
    c = words[0].shape[-1]
    assert c & (c - 1) == 0, f"row width {c} must be a power of two"
    parts = words + (vals,)
    size = 2
    while size <= c:
        d = size // 2
        while d >= 1:
            parts = _row_compare_exchange(parts, d, size)
            d //= 2
        size *= 2
    return like_words(parts[:-1], keys), parts[-1]


def largest_pow2_divisor(m: int, limit: int) -> int:
    """Largest power of two that divides ``m`` and is <= ``limit``.

    The single clamp rule every row-blocked kernel uses to turn a
    block-count bound into a grid-compatible block size.
    """
    b = 1
    while b * 2 <= limit and m % (b * 2) == 0:
        b *= 2
    return b


def auto_block_rows(
    m: int, t: int, vmem_budget_bytes: int = _VMEM_BUDGET_BYTES,
    num_words: int = 1,
) -> int:
    """Largest power-of-two divisor of ``m`` whose (block_rows, T) block
    fits the VMEM budget.

    Args:
        m: tile count.
        t: tile width.
        vmem_budget_bytes: VMEM to fill (default 8 MiB).
        num_words: uint32 key words per element; the block holds
            2*(num_words+1) buffers (in+out, words+values) of
            block_rows*T*4 bytes each.
    """
    per_row = 2 * (num_words + 1) * 4 * t
    return largest_pow2_divisor(m, max(vmem_budget_bytes // per_row, 1))


def effective_block_rows(
    m: int, t: int, block_rows: int | None, num_words: int = 1
) -> int:
    """Resolve a requested block_rows against an actual tile count: None
    = auto VMEM fill; an explicit power of two is an UPPER BOUND, clamped
    to the largest power-of-two divisor of ``m`` (recursion levels with
    odd row counts degrade gracefully to smaller blocks)."""
    if block_rows is None:
        return auto_block_rows(m, t, num_words=num_words)
    assert block_rows >= 1 and block_rows & (block_rows - 1) == 0, block_rows
    return largest_pow2_divisor(m, block_rows)


def _block_kernel(*refs, num_words: int, num_samples: int, sort_rows):
    """Kernel body: refs = num_words+1 inputs (key words + vals),
    num_words+1 outputs, and num_words+1 sample outputs iff sampling.
    ``sort_rows`` is the row-sort network applied to the VMEM block —
    the bitonic network by default; the radix-rank and merge-path
    strategies (kernels/radix.py, kernels/merge.py) plug theirs in
    (DESIGN.md §8)."""
    nw1 = num_words + 1
    in_refs, out_refs = refs[:nw1], refs[nw1:2 * nw1]
    words = tuple(r[...] for r in in_refs[:num_words])  # (block_rows, T) each
    vals = in_refs[num_words][...]
    words, vals = sort_rows(words, vals)
    words = as_words(words)
    for r, w in zip(out_refs, words + (vals,)):
        r[...] = w
    if num_samples:
        samp_refs = refs[2 * nw1:]
        b, t = vals.shape
        chunk = t // num_samples
        # Sample j of a sorted row is element (j+1)*T/s - 1 == the last
        # element of chunk j — a reshape + slice, no gather needed.
        for r, w in zip(samp_refs, words + (vals,)):
            r[...] = w.reshape(b, num_samples, chunk)[:, :, -1]


def tile_sort_call(words, vals, num_samples: int, block_rows,
                   interpret: bool, sort_rows=None):
    """Shared row-blocked pallas launch for every local-sort strategy:
    grid over (block_rows, T) blocks, optional fused sample epilogue.
    ``sort_rows(words_tuple, vals) -> (words, vals)`` sorts each row of
    the block; None selects the bitonic network."""
    if sort_rows is None:
        sort_rows = bitonic_network_rows
    nw = len(words)
    m, t = words[0].shape
    assert vals.shape == (m, t)
    assert all(w.dtype == jnp.uint32 and w.shape == (m, t) for w in words)
    assert vals.dtype == jnp.int32
    block_rows = effective_block_rows(m, t, block_rows, num_words=nw)
    if num_samples:
        assert t % num_samples == 0, (t, num_samples)

    grid = (m // block_rows,)
    blk = pl.BlockSpec((block_rows, t), lambda i: (i, 0))
    in_specs = [blk] * (nw + 1)
    out_specs = [blk] * (nw + 1)
    out_shape = [jax.ShapeDtypeStruct((m, t), jnp.uint32)] * nw + [
        jax.ShapeDtypeStruct((m, t), jnp.int32)
    ]
    if num_samples:
        sblk = pl.BlockSpec((block_rows, num_samples), lambda i: (i, 0))
        out_specs += [sblk] * (nw + 1)
        out_shape += [jax.ShapeDtypeStruct((m, num_samples), jnp.uint32)] * nw
        out_shape += [jax.ShapeDtypeStruct((m, num_samples), jnp.int32)]
    return pl.pallas_call(
        functools.partial(
            _block_kernel, num_words=nw, num_samples=num_samples,
            sort_rows=sort_rows,
        ),
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        # Blocks are independent: let Mosaic parallelize the grid axis.
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel",)
        ),
        interpret=interpret,
    )(*words, vals)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def sort_tiles_kv(
    keys,
    vals: jax.Array,
    *,
    block_rows: int | None = None,
    interpret: bool = True,
):
    """Sort each row of (m, T) keys/vals independently, lexicographically.

    Args:
        keys: uint32 canonical sort-key words — a single (m, T) array or
            a tuple of word arrays (msw first), T a power of two.
        vals: int32 payload (original indices for stability), same shape.
        block_rows: tiles sorted per grid program (None = auto VMEM fill;
            explicit values are clamped, see :func:`effective_block_rows`).
            ``block_rows=1`` reproduces the per-tile baseline layout.
    Returns:
        (sorted_keys in the input structure, sorted_vals), each row
        ascending in the lexicographic (*words, payload) order.
    """
    words = as_words(keys)
    out = tile_sort_call(words, vals, 0, block_rows, interpret)
    return like_words(tuple(out[:-1]), keys), out[-1]


@functools.partial(
    jax.jit, static_argnames=("num_samples", "block_rows", "interpret")
)
def sort_tiles_sample_kv(
    keys,
    vals: jax.Array,
    *,
    num_samples: int,
    block_rows: int | None = None,
    interpret: bool = True,
):
    """Row-blocked tile sort with Step-3 sample extraction fused in.

    Args:
        keys/vals/block_rows: as :func:`sort_tiles_kv`.
        num_samples: s equidistant samples per sorted tile; must divide T.
    Returns:
        (sorted_keys (m, T), sorted_vals (m, T),
         sample_keys (m, s), sample_vals (m, s)) — keys in the input
        structure; sample j of row i is sorted element (j+1)*T/s - 1,
        the paper's s equidistant local samples, emitted while the
        sorted block is still in VMEM.
    """
    words = as_words(keys)
    nw = len(words)
    out = tile_sort_call(words, vals, num_samples, block_rows, interpret)
    return (
        like_words(tuple(out[:nw]), keys),
        out[nw],
        like_words(tuple(out[nw + 1:2 * nw + 1]), keys),
        out[2 * nw + 1],
    )
