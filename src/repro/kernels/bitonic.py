"""Pallas TPU kernel: bitonic (key, value) sort of VMEM-resident tiles.

This is the TPU adaptation of Steps 2/4/9 of GPU BUCKET SORT (Dehne &
Zaboli 2010).  The paper sorts 2K-item sublists per SM in shared memory
with a bitonic network because it is branch-free and SIMD-perfect; the
same argument holds on the TPU VPU: every compare-exchange pass is a
reshape + vectorized min/max/select with *no* data-dependent control
flow, so the whole network lowers to straight-line vector code.

Layout notes (target = TPU v5e):
  * One grid program sorts one tile of ``tile`` keys+values held in VMEM.
  * ``tile`` must be a power of two and a multiple of 128 (lane width)
    so the (nb, 2, d) reshapes stay lane-aligned for d >= 128.  Strides
    d < 128 become intra-lane shuffles; Mosaic handles them, and a
    production-tuned variant would switch to sublane rotates there —
    that is a lowering detail, not an algorithmic one.
  * Comparison is LEXICOGRAPHIC on (key, value).  The caller passes the
    original element index as the value, which (a) makes every compared
    pair unique so the regular-sampling bucket bound ≤ 2n/s holds for
    any duplicate distribution, and (b) makes the sort STABLE.

Keys are canonical uint32 (see ``ops.to_sortable``); values are int32.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _compare_exchange(keys, vals, d: int, size: int):
    """One bitonic compare-exchange pass at stride ``d`` within ``size`` blocks.

    keys/vals: 1-D arrays of length T (power of two).  Element i is paired
    with i ^ d; direction is ascending iff (i & size) == 0.
    """
    t = keys.shape[0]
    nb = t // (2 * d)
    k3 = keys.reshape(nb, 2, d)
    v3 = vals.reshape(nb, 2, d)
    # Global index of the low element of block b is 2*b*d (+ lane offset < d),
    # and d <= size/2, so bit log2(size) is decided purely by the block id.
    blk = jax.lax.broadcasted_iota(jnp.int32, (nb, 1), 0)
    asc = ((2 * blk * d) & size) == 0  # (nb, 1) bool

    klo, khi = k3[:, 0, :], k3[:, 1, :]
    vlo, vhi = v3[:, 0, :], v3[:, 1, :]
    gt = (klo > khi) | ((klo == khi) & (vlo > vhi))  # lexicographic
    swap = jnp.where(asc, gt, ~gt)

    nk_lo = jnp.where(swap, khi, klo)
    nk_hi = jnp.where(swap, klo, khi)
    nv_lo = jnp.where(swap, vhi, vlo)
    nv_hi = jnp.where(swap, vlo, vhi)

    keys = jnp.stack((nk_lo, nk_hi), axis=1).reshape(t)
    vals = jnp.stack((nv_lo, nv_hi), axis=1).reshape(t)
    return keys, vals


def bitonic_network(keys, vals):
    """Full bitonic sorting network on 1-D (keys, vals); T = power of two.

    Unrolled at trace time: log2(T)*(log2(T)+1)/2 vectorized passes.
    Shared by the Pallas kernel body and the pure-jnp reference path.
    """
    t = keys.shape[0]
    assert t & (t - 1) == 0, f"tile size {t} must be a power of two"
    size = 2
    while size <= t:
        d = size // 2
        while d >= 1:
            keys, vals = _compare_exchange(keys, vals, d, size)
            d //= 2
        size *= 2
    return keys, vals


def _bitonic_kernel(k_ref, v_ref, ko_ref, vo_ref):
    keys = k_ref[0, :]
    vals = v_ref[0, :]
    keys, vals = bitonic_network(keys, vals)
    ko_ref[0, :] = keys
    vo_ref[0, :] = vals


@functools.partial(jax.jit, static_argnames=("interpret",))
def sort_tiles_kv(keys: jax.Array, vals: jax.Array, *, interpret: bool = True):
    """Sort each row of (m, T) keys/vals independently, lexicographically.

    keys: uint32 canonical sort keys, shape (m, T), T a power of two.
    vals: int32 payload (original indices for stability), same shape.
    Returns (sorted_keys, sorted_vals), each row ascending.
    """
    m, t = keys.shape
    assert vals.shape == (m, t)
    assert keys.dtype == jnp.uint32 and vals.dtype == jnp.int32
    grid = (m,)
    blk_in = pl.BlockSpec((1, t), lambda i: (i, 0))
    return pl.pallas_call(
        _bitonic_kernel,
        grid=grid,
        in_specs=[blk_in, blk_in],
        out_specs=[blk_in, blk_in],
        out_shape=[
            jax.ShapeDtypeStruct((m, t), jnp.uint32),
            jax.ShapeDtypeStruct((m, t), jnp.int32),
        ],
        interpret=interpret,
    )(keys, vals)


# --- Row-wise bitonic along the last axis (used by the top-k kernel and the
# --- pure-jnp tile path, where many independent rows are sorted at once).


def _row_compare_exchange(keys, vals, d: int, size: int):
    """Compare-exchange along the LAST axis of (..., C) arrays."""
    c = keys.shape[-1]
    lead = keys.shape[:-1]
    nb = c // (2 * d)
    k3 = keys.reshape(lead + (nb, 2, d))
    v3 = vals.reshape(lead + (nb, 2, d))
    blk = jax.lax.broadcasted_iota(jnp.int32, (nb, 1), 0)
    asc = ((2 * blk * d) & size) == 0  # (nb, 1), broadcasts over leading dims

    klo, khi = k3[..., 0, :], k3[..., 1, :]
    vlo, vhi = v3[..., 0, :], v3[..., 1, :]
    gt = (klo > khi) | ((klo == khi) & (vlo > vhi))
    swap = jnp.where(asc, gt, ~gt)

    nk = jnp.stack(
        (jnp.where(swap, khi, klo), jnp.where(swap, klo, khi)), axis=-2
    ).reshape(lead + (c,))
    nv = jnp.stack(
        (jnp.where(swap, vhi, vlo), jnp.where(swap, vlo, vhi)), axis=-2
    ).reshape(lead + (c,))
    return nk, nv


def bitonic_network_rows(keys, vals):
    """Bitonic sort along the last axis of (..., C); C = power of two."""
    c = keys.shape[-1]
    assert c & (c - 1) == 0, f"row width {c} must be a power of two"
    size = 2
    while size <= c:
        d = size // 2
        while d >= 1:
            keys, vals = _row_compare_exchange(keys, vals, d, size)
            d //= 2
        size *= 2
    return keys, vals
