"""Pallas TPU kernel: row-blocked bitonic (key, value) sort of VMEM tiles.

This is the TPU adaptation of Steps 2/4/9 of GPU BUCKET SORT (Dehne &
Zaboli 2010).  The paper sorts 2K-item sublists per SM in shared memory
with a bitonic network because it is branch-free and SIMD-perfect; the
same argument holds on the TPU VPU: every compare-exchange pass is a
reshape + vectorized min/max/select with *no* data-dependent control
flow, so the whole network lowers to straight-line vector code.

Layout notes (target = TPU v5e; see DESIGN.md §3):
  * One grid program sorts a ``(block_rows, T)`` BLOCK of tiles held in
    VMEM, running the compare-exchange network along the lane axis of
    all ``block_rows`` rows at once.  With ``block_rows >= 8`` every
    vector op is a dense (8-sublane x 128-lane) tile, instead of the
    1/8-occupancy (1, T) ops the per-tile formulation issues.
  * ``block_rows`` is auto-picked by :func:`auto_block_rows` to fill a
    VMEM budget; the grid axis is declared ``parallel`` (programs are
    independent) so Mosaic may pipeline/parallelize blocks freely.
  * ``T`` must be a power of two and a multiple of 128 (lane width)
    so the (nb, 2, d) reshapes stay lane-aligned for d >= 128.  Strides
    d < 128 become intra-lane shuffles; Mosaic handles them, and a
    production-tuned variant would switch to sublane rotates there —
    that is a lowering detail, not an algorithmic one.
  * Comparison is LEXICOGRAPHIC on (key, value).  The caller passes the
    original element index as the value, which (a) makes every compared
    pair unique so the regular-sampling bucket bound ≤ 2n/s holds for
    any duplicate distribution, and (b) makes the sort STABLE.
  * Step 3 of the algorithm (equidistant sample extraction) is FUSED
    into the kernel as an optional epilogue output: the s per-tile
    samples are the last element of each T/s chunk of the sorted row,
    a pure reshape + slice while the block is still VMEM-resident.
    This removes one full HBM read of the sorted tiles (DESIGN.md §3).

Keys are canonical uint32 (see ``ops.to_sortable``); values are int32.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# VMEM budget for one grid program's block: in + out, keys + values
# (4 buffers of block_rows * T * 4 bytes).  8 MiB of the ~16 MiB/core
# leaves headroom for the network's double-buffered temporaries.
_VMEM_BUDGET_BYTES = 8 * 1024 * 1024


def _compare_exchange(keys, vals, d: int, size: int):
    """One bitonic compare-exchange pass at stride ``d`` within ``size`` blocks.

    keys/vals: 1-D arrays of length T (power of two).  Element i is paired
    with i ^ d; direction is ascending iff (i & size) == 0.
    """
    t = keys.shape[0]
    nb = t // (2 * d)
    k3 = keys.reshape(nb, 2, d)
    v3 = vals.reshape(nb, 2, d)
    # Global index of the low element of block b is 2*b*d (+ lane offset < d),
    # and d <= size/2, so bit log2(size) is decided purely by the block id.
    blk = jax.lax.broadcasted_iota(jnp.int32, (nb, 1), 0)
    asc = ((2 * blk * d) & size) == 0  # (nb, 1) bool

    klo, khi = k3[:, 0, :], k3[:, 1, :]
    vlo, vhi = v3[:, 0, :], v3[:, 1, :]
    gt = (klo > khi) | ((klo == khi) & (vlo > vhi))  # lexicographic
    swap = jnp.where(asc, gt, ~gt)

    nk_lo = jnp.where(swap, khi, klo)
    nk_hi = jnp.where(swap, klo, khi)
    nv_lo = jnp.where(swap, vhi, vlo)
    nv_hi = jnp.where(swap, vlo, vhi)

    keys = jnp.stack((nk_lo, nk_hi), axis=1).reshape(t)
    vals = jnp.stack((nv_lo, nv_hi), axis=1).reshape(t)
    return keys, vals


def bitonic_network(keys, vals):
    """Full bitonic sorting network on 1-D (keys, vals); T = power of two.

    Unrolled at trace time: log2(T)*(log2(T)+1)/2 vectorized passes.
    Kept as the 1-D reference formulation (and the per-tile baseline in
    ``benchmarks/step_breakdown.py``); the kernel path uses the row-
    blocked :func:`bitonic_network_rows`.
    """
    t = keys.shape[0]
    assert t & (t - 1) == 0, f"tile size {t} must be a power of two"
    size = 2
    while size <= t:
        d = size // 2
        while d >= 1:
            keys, vals = _compare_exchange(keys, vals, d, size)
            d //= 2
        size *= 2
    return keys, vals


# --- Row-wise bitonic along the last axis: shared by the blocked tile-sort
# --- kernel, the top-k kernel, and the pure-jnp reference path.


def _row_compare_exchange(keys, vals, d: int, size: int):
    """Compare-exchange along the LAST axis of (..., C) arrays."""
    c = keys.shape[-1]
    lead = keys.shape[:-1]
    nb = c // (2 * d)
    k3 = keys.reshape(lead + (nb, 2, d))
    v3 = vals.reshape(lead + (nb, 2, d))
    blk = jax.lax.broadcasted_iota(jnp.int32, (nb, 1), 0)
    asc = ((2 * blk * d) & size) == 0  # (nb, 1), broadcasts over leading dims

    klo, khi = k3[..., 0, :], k3[..., 1, :]
    vlo, vhi = v3[..., 0, :], v3[..., 1, :]
    gt = (klo > khi) | ((klo == khi) & (vlo > vhi))
    swap = jnp.where(asc, gt, ~gt)

    nk = jnp.stack(
        (jnp.where(swap, khi, klo), jnp.where(swap, klo, khi)), axis=-2
    ).reshape(lead + (c,))
    nv = jnp.stack(
        (jnp.where(swap, vhi, vlo), jnp.where(swap, vlo, vhi)), axis=-2
    ).reshape(lead + (c,))
    return nk, nv


def bitonic_network_rows(keys, vals):
    """Bitonic sort along the last axis of (..., C); C = power of two."""
    c = keys.shape[-1]
    assert c & (c - 1) == 0, f"row width {c} must be a power of two"
    size = 2
    while size <= c:
        d = size // 2
        while d >= 1:
            keys, vals = _row_compare_exchange(keys, vals, d, size)
            d //= 2
        size *= 2
    return keys, vals


def largest_pow2_divisor(m: int, limit: int) -> int:
    """Largest power of two that divides ``m`` and is <= ``limit``.

    The single clamp rule every row-blocked kernel uses to turn a
    block-count bound into a grid-compatible block size.
    """
    b = 1
    while b * 2 <= limit and m % (b * 2) == 0:
        b *= 2
    return b


def auto_block_rows(
    m: int, t: int, vmem_budget_bytes: int = _VMEM_BUDGET_BYTES
) -> int:
    """Largest power-of-two divisor of ``m`` whose (block_rows, T) block
    (4 x uint32/int32 buffers: in/out keys/values) fits the VMEM budget."""
    return largest_pow2_divisor(m, max(vmem_budget_bytes // (4 * 4 * t), 1))


def effective_block_rows(m: int, t: int, block_rows: int | None) -> int:
    """Resolve a requested block_rows against an actual tile count: None
    = auto VMEM fill; an explicit power of two is an UPPER BOUND, clamped
    to the largest power-of-two divisor of ``m`` (recursion levels with
    odd row counts degrade gracefully to smaller blocks)."""
    if block_rows is None:
        return auto_block_rows(m, t)
    assert block_rows >= 1 and block_rows & (block_rows - 1) == 0, block_rows
    return largest_pow2_divisor(m, block_rows)


def _bitonic_block_kernel(k_ref, v_ref, ko_ref, vo_ref, *rest, num_samples: int):
    keys = k_ref[...]  # (block_rows, T)
    vals = v_ref[...]
    keys, vals = bitonic_network_rows(keys, vals)
    ko_ref[...] = keys
    vo_ref[...] = vals
    if num_samples:
        sk_ref, sv_ref = rest
        b, t = keys.shape
        chunk = t // num_samples
        # Sample j of a sorted row is element (j+1)*T/s - 1 == the last
        # element of chunk j — a reshape + slice, no gather needed.
        sk_ref[...] = keys.reshape(b, num_samples, chunk)[:, :, -1]
        sv_ref[...] = vals.reshape(b, num_samples, chunk)[:, :, -1]


def _sort_tiles_call(keys, vals, num_samples: int, block_rows, interpret: bool):
    m, t = keys.shape
    assert vals.shape == (m, t)
    assert keys.dtype == jnp.uint32 and vals.dtype == jnp.int32
    block_rows = effective_block_rows(m, t, block_rows)
    if num_samples:
        assert t % num_samples == 0, (t, num_samples)

    grid = (m // block_rows,)
    blk = pl.BlockSpec((block_rows, t), lambda i: (i, 0))
    out_specs = [blk, blk]
    out_shape = [
        jax.ShapeDtypeStruct((m, t), jnp.uint32),
        jax.ShapeDtypeStruct((m, t), jnp.int32),
    ]
    if num_samples:
        sblk = pl.BlockSpec((block_rows, num_samples), lambda i: (i, 0))
        out_specs += [sblk, sblk]
        out_shape += [
            jax.ShapeDtypeStruct((m, num_samples), jnp.uint32),
            jax.ShapeDtypeStruct((m, num_samples), jnp.int32),
        ]
    return pl.pallas_call(
        functools.partial(_bitonic_block_kernel, num_samples=num_samples),
        grid=grid,
        in_specs=[blk, blk],
        out_specs=out_specs,
        out_shape=out_shape,
        # Blocks are independent: let Mosaic parallelize the grid axis.
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel",)
        ),
        interpret=interpret,
    )(keys, vals)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def sort_tiles_kv(
    keys: jax.Array,
    vals: jax.Array,
    *,
    block_rows: int | None = None,
    interpret: bool = True,
):
    """Sort each row of (m, T) keys/vals independently, lexicographically.

    keys: uint32 canonical sort keys, shape (m, T), T a power of two.
    vals: int32 payload (original indices for stability), same shape.
    block_rows: tiles sorted per grid program (None = auto VMEM fill;
        explicit values are clamped, see :func:`effective_block_rows`).
        ``block_rows=1`` reproduces the per-tile baseline layout.
    Returns (sorted_keys, sorted_vals), each row ascending.
    """
    sk, sv = _sort_tiles_call(keys, vals, 0, block_rows, interpret)
    return sk, sv


@functools.partial(
    jax.jit, static_argnames=("num_samples", "block_rows", "interpret")
)
def sort_tiles_sample_kv(
    keys: jax.Array,
    vals: jax.Array,
    *,
    num_samples: int,
    block_rows: int | None = None,
    interpret: bool = True,
):
    """Row-blocked tile sort with Step-3 sample extraction fused in.

    Returns (sorted_keys (m, T), sorted_vals (m, T),
             sample_keys (m, s), sample_vals (m, s)) where sample j of
    row i is sorted element (j+1)*T/s - 1 — the paper's s equidistant
    local samples — emitted while the sorted block is still in VMEM.
    """
    return tuple(_sort_tiles_call(keys, vals, num_samples, block_rows, interpret))
