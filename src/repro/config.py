"""Framework configuration: model / parallelism / shapes / training.

One ``ModelConfig`` covers all ten assigned architectures via a periodic
layer pattern: each layer slot is (mixer, ffn) where mixer is attention
(GQA or MLA), a Mamba-2 SSD block, or none, and ffn is a dense MLP, an
MoE (with the paper's sample-sort dispatch), or none.  The decoder
stack = ``layer_pattern`` repeated ``n_layers/len(pattern)`` times and
scanned (fast compiles, remat-friendly).
"""

from __future__ import annotations

import dataclasses
from typing import Literal


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    """Multi-head Latent Attention (DeepSeek-V2 / MiniCPM3 style)."""

    q_lora_rank: int = 768
    kv_lora_rank: int = 256
    qk_nope_head_dim: int = 64
    qk_rope_head_dim: int = 32
    v_head_dim: int = 64


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 64
    top_k: int = 6
    d_ff_expert: int = 1408
    n_shared_experts: int = 0  # shared-expert d_ff = n_shared * d_ff_expert
    capacity_factor: float = 1.25
    dispatch: Literal["sample_sort", "xla_sort", "dense"] = "sample_sort"
    router_dtype: str = "float32"


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """Mamba-2 (SSD) block geometry."""

    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    chunk: int = 256


@dataclasses.dataclass(frozen=True)
class LayerSlot:
    mixer: Literal["attn", "mla", "mamba", "none"] = "attn"
    ffn: Literal["dense", "moe", "none"] = "dense"


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    n_layers: int = 4
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 4
    d_ff: int = 1024
    vocab: int = 1024
    head_dim: int = 0  # 0 -> d_model // n_heads
    attn_bias: bool = False  # qwen2: bias on QKV projections
    rope_theta: float = 10000.0
    norm: Literal["rmsnorm", "layernorm"] = "rmsnorm"
    norm_eps: float = 1e-5
    activation: Literal["swiglu", "gelu"] = "swiglu"
    tie_embeddings: bool = False
    layer_pattern: tuple[LayerSlot, ...] = (LayerSlot(),)
    mla: MLAConfig | None = None
    moe: MoEConfig | None = None
    ssm: SSMConfig | None = None
    # encoder-decoder (whisper): encoder reuses d_model/heads/d_ff with
    # bidirectional attention; decoder adds cross-attention per layer.
    n_encoder_layers: int = 0
    encoder_positions: int = 1500  # whisper: 30s of audio frames
    # modality frontend stub: inputs include precomputed prefix embeddings
    frontend: Literal["none", "audio", "vision"] = "none"
    frontend_len: int = 0  # patches/frames supplied by the stub
    # dtypes
    param_dtype: str = "bfloat16"
    dtype: str = "bfloat16"  # activation/compute dtype
    # memory
    remat: Literal["none", "full", "dots"] = "full"
    sub_quadratic: bool = False  # True for SSM/hybrid: long_500k cells run
    attn_chunk: int = 1024  # KV block for chunked (flash-style) attention
    loss_chunk: int = 2048  # sequence chunk for the CE loss (no full logits)
    # scan_layers=False unrolls the period loop — used by the dry-run's
    # 1- and 2-period probe compiles because XLA cost_analysis counts a
    # while-loop body ONCE (trip counts are not multiplied in).
    scan_layers: bool = True

    @property
    def dh(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def padded_vocab(self) -> int:
        """Embedding tables padded to 256 (Megatron convention) so the
        vocab dim shards evenly; pad logits are masked in unembed."""
        return ((self.vocab + 255) // 256) * 256

    @property
    def n_periods(self) -> int:
        assert self.n_layers % len(self.layer_pattern) == 0, (
            self.n_layers, len(self.layer_pattern))
        return self.n_layers // len(self.layer_pattern)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""

    name: str  # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    kind: Literal["train", "prefill", "decode"] = "train"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class ParallelConfig:
    """Mesh + sharding policy."""

    mesh_shape: tuple[int, ...] = (16, 16)
    mesh_axes: tuple[str, ...] = ("data", "model")
    fsdp: bool = False  # shard the "embed" dim of params over data axis
    fsdp_axes: tuple[str, ...] = ("data",)
    remat_scan: bool = True
    # distributed-optimization tricks
    grad_accum: int = 1  # microbatch steps (scan)
    compress_grads: bool = False  # int8 all-reduce w/ error feedback (DP path)

    @property
    def batch_axes(self) -> tuple[str, ...]:
        return tuple(a for a in self.mesh_axes if a in ("pod", "data"))


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    moment_dtype: str = "float32"  # "bfloat16" for low-mem (jamba-398b)


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    steps: int = 200
    log_every: int = 10
    ckpt_every: int = 100
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_keep: int = 3
    seed: int = 0
    optimizer: OptimizerConfig = OptimizerConfig()


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    """Everything the launcher needs for one --arch id."""

    model: ModelConfig
    shapes: tuple[str, ...] = ("train_4k", "prefill_32k", "decode_32k")
    skip_notes: str = ""
    fsdp: bool = False
    moment_dtype: str = "float32"
