"""Checkpointing: atomic, per-leaf, keep-k, async — pure numpy+json.

Layout:  <dir>/step_<N>/
           manifest.json        {step, keys, dtypes, shapes}
           <flatkey>.npy        one file per pytree leaf

Fault-tolerance properties:
  * atomic: written into step_<N>.tmp then os.rename'd — a crash mid-save
    never corrupts the latest checkpoint;
  * restartable: ``latest_step`` scans for complete manifests only;
  * keep-k GC after each successful save;
  * async: AsyncCheckpointer snapshots device arrays to host then writes
    on a worker thread so the train loop never blocks on disk;
  * sharding-aware restore: pass shardings to place leaves directly.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in leaves:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path
        )
        out[key] = leaf
    return out, treedef


def save(path: str, step: int, tree) -> str:
    """Blocking atomic save.  Returns the final directory.

    The staging directory is unique per attempt (pid + thread id), so
    two concurrent saves of the same step — e.g. an abandoned async
    writer racing a post-restart re-save — never touch each other's
    files; the loser of the final rename discards its staging dir.
    """
    flat, _ = _flatten(tree)
    final = os.path.join(path, f"step_{step:08d}")
    tmp = f"{final}.tmp.{os.getpid()}.{threading.get_ident()}"
    shutil.rmtree(tmp, ignore_errors=True)
    os.makedirs(tmp, exist_ok=True)
    manifest = {"step": step, "keys": [], "dtypes": {}, "shapes": {}}
    for key, leaf in flat.items():
        arr = np.asarray(leaf)
        fn = key.replace("/", "__") + ".npy"
        np.save(os.path.join(tmp, fn), arr)
        manifest["keys"].append(key)
        manifest["dtypes"][key] = str(arr.dtype)
        manifest["shapes"][key] = list(arr.shape)
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final, ignore_errors=True)
    try:
        os.rename(tmp, final)
    except OSError:
        if not os.path.exists(os.path.join(final, "manifest.json")):
            raise  # a real failure, not a concurrent publish
        # Lost the publish race to a concurrent save of the same step
        # (same state: steps are deterministic); keep the winner's copy.
        shutil.rmtree(tmp, ignore_errors=True)
    return final


def latest_step(path: str) -> int | None:
    """Largest step with a COMPLETE manifest (ignores .tmp partials)."""
    if not os.path.isdir(path):
        return None
    steps = []
    for name in os.listdir(path):
        if name.startswith("step_") and ".tmp" not in name:
            if os.path.exists(os.path.join(path, name, "manifest.json")):
                steps.append(int(name[5:]))
    return max(steps) if steps else None


def restore(path: str, step: int, like, shardings=None):
    """Restore into the structure of ``like`` (a pytree of arrays/SDS).

    shardings: optional matching pytree of jax.sharding.Sharding — leaves
    are device_put directly to their shards (multi-host friendly).
    """
    d = os.path.join(path, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    flat_like, treedef = _flatten(like)
    flat_sh, _ = _flatten(shardings) if shardings is not None else ({}, None)
    vals = []
    for key in flat_like:
        assert key in manifest["dtypes"], f"checkpoint missing leaf {key}"
        arr = np.load(os.path.join(d, key.replace("/", "__") + ".npy"))
        want = flat_like[key]
        assert tuple(arr.shape) == tuple(want.shape), (key, arr.shape, want.shape)
        if key in flat_sh and flat_sh[key] is not None:
            vals.append(jax.device_put(arr, flat_sh[key]))
        else:
            vals.append(jax.device_put(arr))
    return jax.tree_util.tree_unflatten(treedef, vals)


def gc_keep_k(path: str, keep: int, stale_tmp_secs: float = 3600.0):
    """Keep the newest ``keep`` complete checkpoints; also sweep staging
    dirs (``step_*.tmp.*``) untouched for ``stale_tmp_secs`` — orphans
    of crashed writers, whose pid-unique names nothing else reclaims."""
    if not os.path.isdir(path):
        return
    steps = sorted(
        int(n[5:]) for n in os.listdir(path)
        if n.startswith("step_") and ".tmp" not in n
        and os.path.exists(os.path.join(path, n, "manifest.json"))
    )
    for s in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(os.path.join(path, f"step_{s:08d}"), ignore_errors=True)
    now = time.time()
    for n in os.listdir(path):
        if n.startswith("step_") and ".tmp" in n:
            p = os.path.join(path, n)
            try:
                if now - os.path.getmtime(p) > stale_tmp_secs:
                    shutil.rmtree(p, ignore_errors=True)
            except OSError:
                pass  # disappeared mid-check (its writer finished)


class AsyncCheckpointer:
    """Non-blocking checkpoints: snapshot to host, write on a thread."""

    def __init__(self, path: str, keep: int = 3):
        self.path, self.keep = path, keep
        self._thread: threading.Thread | None = None
        self.last_error: Exception | None = None

    def save(self, step: int, tree):
        self.wait()  # one in flight at a time
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)

        def work():
            try:
                save(self.path, step, host_tree)
                gc_keep_k(self.path, self.keep)
            except Exception as e:  # surfaced on next wait()
                self.last_error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self.last_error is not None:
            err, self.last_error = self.last_error, None
            raise err
