"""Partial deterministic sample sort: top-k of a large array (beyond-paper).

Serving needs top-k / top-p over vocab-sized logits (50k-164k).  A full
sort wastes work; instead run ONE bucket round of Algorithm 1 (steps
1-7) to locate a splitter threshold θ whose global rank >= k, gather the
< k + B candidates below θ (B = the paper's guaranteed bucket capacity —
that static bound is exactly what makes the candidate buffer static),
and fully sort only the candidates.

Work: O(n) tile sort + O((k+B) log(k+B))  vs  O(n log n) full sort.

Everything here operates on "smallest-k of canonical key words"; the
public entries encode with a ``descending=True`` key codec
(``core/key_codec``), under which ascending canonical order ==
descending score order and ties break toward the smaller index,
matching jax.lax.top_k.  All codec dtypes are supported (64-bit scores
use two-word keys and need x64 mode); ``cfg.descending`` is ignored —
top-k is descending by definition.

``topk_batched`` runs the same partial round on every row of a
serving-shaped (B, vocab) batch in ONE launch (DESIGN.md §5): tiles of
all rows sort together, splitters/thresholds are per row, and the
candidate pack is a scatter-free gather (binary search over the per-row
tile candidate-count prefix sums, like the step-8 relocation).

Scheduling follows the planner/executor split (DESIGN.md §7): the
one-round geometry (lp, m, cap, ccap, kernel block sizes, resolved
backend) is computed once by ``core/plan.build_topk_plan`` and the
jit'd bodies below consume the frozen ``TopkPlan`` as their static
argument instead of re-deriving it per trace.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import guard
from repro.core.bucket_sort import _chunk_search
from repro.core.key_codec import codec_for
from repro.core.plan import TopkPlan, build_topk_plan
from repro.core.sort_config import DEFAULT_CONFIG, SortConfig, next_pow2
from repro.kernels import ops

_MAXU = jnp.uint32(0xFFFFFFFF)
_IMAX = jnp.int32(2**31 - 1)


def _pad_pow2(kw, v2):
    """Pad (r, L) words/payloads to the next power of two with
    (all-ones, IMAX) pairs (sort last; never candidates)."""
    r, length = kw[0].shape
    lp = next_pow2(length)
    if lp == length:
        return kw, v2
    pk = jnp.full((r, lp - length), _MAXU, jnp.uint32)
    pv = jnp.full((r, lp - length), _IMAX, jnp.int32)
    return (
        tuple(jnp.concatenate([w, pk], 1) for w in kw),
        jnp.concatenate([v2, pv], 1),
    )


def _sort_small(kw, v1, tplan: TopkPlan):
    """Bitonic sort of a single row (pads with (all-ones, IMAX) go last)."""
    n = kw[0].shape[0]
    skw, sv = ops.sort_tiles(
        *_pad_pow2(tuple(w[None] for w in kw), v1[None]),
        impl=tplan.impl, interpret=tplan.interpret,
        strategy=tplan.strategy, radix_bits=tplan.radix_bits,
        merge_run=tplan.merge_run,
    )
    return tuple(w[0, :n] for w in skw), sv[0, :n]


@functools.partial(jax.jit, static_argnames=("tplan",))
def _smallest_k(kw, tplan: TopkPlan):
    """Ascending smallest-k of canonical key words; payload = original
    index.  kw: tuple of (n,) uint32 word arrays (msw first); every
    static quantity (lp, m, cap, ccap, kernel geometry) is read off the
    :class:`repro.core.plan.TopkPlan`."""
    (n,) = kw[0].shape
    k = tplan.k
    t, s = tplan.tile, tplan.s
    lp = tplan.lp
    vals = jnp.arange(n, dtype=jnp.int32)
    if lp > n:  # pad with MAX pairs: never candidates for smallest-k
        kw = tuple(
            jnp.concatenate([w, jnp.full((lp - n,), _MAXU, jnp.uint32)])
            for w in kw
        )
        vals = jnp.concatenate([vals, jnp.full((lp - n,), _IMAX, jnp.int32)])
    m = tplan.m

    # steps 1-2: tile sort
    tkw, tv = ops.sort_tiles(
        tuple(w.reshape(m, t) for w in kw), vals.reshape(m, t),
        impl=tplan.impl, interpret=tplan.interpret,
        block_rows=tplan.block_rows, strategy=tplan.strategy,
        radix_bits=tplan.radix_bits, merge_run=tplan.merge_run,
    )

    # steps 3-5: samples -> sorted samples -> s-1 splitters
    samp_idx = (jnp.arange(1, s + 1, dtype=jnp.int32) * (t // s)) - 1
    skw, sv = _sort_small(
        tuple(w[:, samp_idx].reshape(m * s) for w in tkw),
        tv[:, samp_idx].reshape(m * s), tplan,
    )
    sp_idx = (jnp.arange(1, s, dtype=jnp.int32) * (m * s)) // s
    spkw = tuple(jnp.broadcast_to(w[sp_idx], (m, s - 1)) for w in skw)
    spv = jnp.broadcast_to(sv[sp_idx], (m, s - 1))

    # step 6: ranks
    ranks = ops.splitter_ranks(
        tkw, tv, spkw, spv, impl=tplan.impl, interpret=tplan.interpret
    )  # (m, s-1)
    glob_ranks = ranks.sum(axis=0, dtype=jnp.int32)  # (s-1,)

    # θ = smallest splitter with global rank >= k; candidates = elements < θ.
    # Bucket bound: candidate count < k + cap.  If no splitter qualifies,
    # the last bucket alone exceeds lp - k, hence cap > lp - k and the
    # static capacity (plan-carried) already covers taking ALL elements.
    ccap = tplan.ccap
    qualifies = glob_ranks >= k  # monotone
    any_q = jnp.any(qualifies)
    theta = jnp.argmax(qualifies).astype(jnp.int32)  # first True (or 0)
    tile_rank = jnp.where(
        any_q,
        jnp.take_along_axis(
            ranks, jnp.broadcast_to(theta[None, None], (m, 1)), axis=1
        )[:, 0],
        jnp.full((m,), t, jnp.int32),
    )  # (m,) elements of tile i below θ (or all)

    # candidate gather: global candidate slot = (#cands in earlier tiles) + pos
    tile_excl = jnp.cumsum(tile_rank, dtype=jnp.int32) - tile_rank
    pos = jax.lax.broadcasted_iota(jnp.int32, (m, t), 1)
    is_cand = pos < tile_rank[:, None]
    within = tile_excl[:, None] + pos
    dest = jnp.where(is_cand & (within < ccap), within, ccap).reshape(-1)
    ckw = tuple(
        jnp.full((ccap + 1,), _MAXU, jnp.uint32)
        .at[dest].set(w.reshape(-1), mode="drop")[:ccap]
        for w in tkw
    )
    cv = jnp.full((ccap + 1,), _IMAX, jnp.int32)
    cv = cv.at[dest].set(tv.reshape(-1), mode="drop")[:ccap]

    fkw, fv = _sort_small(ckw, cv, tplan)
    return tuple(w[:k] for w in fkw), fv[:k]


def _fallback_topk_plan(n, k, dtype, tplan: TopkPlan, rows: int = 1):
    """Default-config xla stand-in plan for the degradation chain
    (DESIGN.md §11), or None when indistinguishable from ``tplan``."""
    try:
        alt = build_topk_plan(
            n, k, dtype, SortConfig(impl="xla", interpret=False), rows=rows
        )
    except Exception:
        return None
    return None if alt == tplan else alt


def _topk_site(tplan: TopkPlan) -> str:
    return (f"TopkPlan(rows={tplan.rows}, n={tplan.length}, "
            f"k={tplan.k}, impl={tplan.impl})")


def _reference_topk(x, k, codec, check):
    """Last rung of the chain: jax.lax.top_k (no plan machinery)."""
    v, i = jax.lax.top_k(x, k)
    i = i.astype(jnp.int32)
    if check != "off":
        guard.check_topk(x, v, i, k, check, codec)
    return v, i


def topk(x: jax.Array, k: int, cfg: SortConfig = DEFAULT_CONFIG):
    """Top-k (descending) values + original indices of 1-D x.

    Args:
        x: 1-D scores in any codec dtype (int/uint/float 8..64-bit,
            bool; 64-bit needs x64 mode — see ``core/key_codec``).
        k: 1 <= k <= len(x).
        cfg: pipeline knobs (``cfg.descending`` is ignored: top-k is
            descending by definition; ``cfg.check`` enables runtime
            invariants and the degradation chain of DESIGN.md §11).
    Returns:
        (values (k,) in x.dtype, indices (k,) int32); ties break toward
        the smaller index (matches jax.lax.top_k).

    Example:
        >>> import jax.numpy as jnp
        >>> from repro.core import partial_sort
        >>> v, i = partial_sort.topk(jnp.asarray([1.0, 9.0, 4.0, 9.0]), 2)
        >>> v, i
        (Array([9., 9.], dtype=float32), Array([1, 3], dtype=int32))
    """
    n = x.shape[0]
    assert 1 <= k <= n
    guard.validate_check(cfg.check)
    codec = codec_for(x.dtype, descending=True)
    kw = codec.encode(x)  # ascending canonical == descending score

    def run(tplan):
        if n <= tplan.direct_max:
            fkw, fv = _sort_small(kw, jnp.arange(n, dtype=jnp.int32), tplan)
            fkw, fv = tuple(w[:k] for w in fkw), fv[:k]
        else:
            fkw, fv = _smallest_k(kw, tplan)
        v, i = codec.decode(fkw), fv
        if cfg.check != "off":
            guard.check_topk(x, v, i, k, cfg.check, codec)
        return v, i

    tplan = build_topk_plan(n, k, x.dtype, cfg)
    try:
        return run(tplan)
    except Exception as e1:
        alt = _fallback_topk_plan(n, k, x.dtype, tplan)
        if alt is not None:
            guard.record_degradation(
                _topk_site(tplan), "fallback",
                f"impl={tplan.impl} topk plan", "default xla stand-in plan",
                e1)
            try:
                return run(alt)
            except Exception as e2:
                e1 = e2
        guard.record_degradation(
            _topk_site(tplan), "fallback",
            "partial-sort top-k", "jax.lax.top_k reference", e1)
        return _reference_topk(x, k, codec, cfg.check)


# ----------------------------------------------------------------------
# Batched partial sort: top-k of every row of (B, vocab) in one launch
# ----------------------------------------------------------------------


def _sort_small_rows(kw, v2, tplan: TopkPlan):
    """Bitonic sort of each row of (r, L) (pads with (all-ones, IMAX) last)."""
    n = kw[0].shape[1]
    skw, sv = ops.sort_tiles(
        *_pad_pow2(kw, v2), impl=tplan.impl, interpret=tplan.interpret,
        block_rows=tplan.raw_block_rows, strategy=tplan.strategy,
        radix_bits=tplan.radix_bits, merge_run=tplan.merge_run,
    )
    return tuple(w[:, :n] for w in skw), sv[:, :n]


@functools.partial(jax.jit, static_argnames=("tplan",))
def _smallest_k_rows(kw, tplan: TopkPlan):
    """Per-row ascending smallest-k of (B, n) canonical key words;
    payload = original column index.  One bucket round for the whole
    batch (geometry plan-carried); θ and the candidate set are per row."""
    b, n = kw[0].shape
    k = tplan.k
    t, s = tplan.tile, tplan.s
    lp = tplan.lp
    vals = jnp.broadcast_to(jnp.arange(n, dtype=jnp.int32)[None, :], (b, n))
    if lp > n:  # pad with MAX pairs: never candidates for smallest-k
        kw = tuple(
            jnp.concatenate(
                [w, jnp.full((b, lp - n), _MAXU, jnp.uint32)], axis=1
            )
            for w in kw
        )
        vals = jnp.concatenate(
            [vals, jnp.full((b, lp - n), _IMAX, jnp.int32)], axis=1
        )
    m = tplan.m

    # steps 1-2: tile sort, all rows' tiles in one launch
    tkw, tv = ops.sort_tiles(
        tuple(w.reshape(b * m, t) for w in kw), vals.reshape(b * m, t),
        impl=tplan.impl, interpret=tplan.interpret,
        block_rows=tplan.block_rows, strategy=tplan.strategy,
        radix_bits=tplan.radix_bits, merge_run=tplan.merge_run,
    )

    # steps 3-5: per-row samples -> sorted sample rows -> s-1 splitters
    samp_idx = (jnp.arange(1, s + 1, dtype=jnp.int32) * (t // s)) - 1
    sskw, ssv = _sort_small_rows(
        tuple(w[:, samp_idx].reshape(b, m * s) for w in tkw),
        tv[:, samp_idx].reshape(b, m * s),
        tplan,
    )
    sp_idx = (jnp.arange(1, s, dtype=jnp.int32) * (m * s)) // s
    spkw_t = tuple(jnp.repeat(w[:, sp_idx], m, axis=0) for w in sskw)
    spv_t = jnp.repeat(ssv[:, sp_idx], m, axis=0)  # (b*m, s-1)

    # step 6: ranks, reduced per row
    ranks = ops.splitter_ranks(
        tkw, tv, spkw_t, spv_t, impl=tplan.impl, interpret=tplan.interpret
    ).reshape(b, m, s - 1)
    glob_ranks = ranks.sum(axis=1, dtype=jnp.int32)  # (b, s-1)

    # Per-row θ: smallest splitter with global rank >= k (see _smallest_k
    # for why ccap always covers the candidate count).
    ccap = tplan.ccap
    qualifies = glob_ranks >= k  # (b, s-1), monotone per row
    any_q = jnp.any(qualifies, axis=1)  # (b,)
    theta = jnp.argmax(qualifies, axis=1).astype(jnp.int32)  # (b,)
    tile_rank = jnp.where(
        any_q[:, None],
        jnp.take_along_axis(ranks, theta[:, None, None], axis=2)[:, :, 0],
        jnp.full((b, m), t, jnp.int32),
    )  # (b, m) elements of each tile below the row's θ (or all)

    # Scatter-free candidate pack: slot p of row q reads the tile whose
    # candidate-count prefix interval covers p, at its first tile_rank
    # positions (the candidates are a sorted tile's prefix).
    tile_excl = jnp.cumsum(tile_rank, axis=1, dtype=jnp.int32) - tile_rank
    total = tile_rank.sum(axis=1, dtype=jnp.int32)  # (b,)
    p = jax.lax.broadcasted_iota(jnp.int32, (b, ccap), 1)
    src_tile = _chunk_search(tile_excl, p)  # (b, ccap)
    src_off = jnp.take_along_axis(tile_excl, src_tile, axis=1)
    row_base = jax.lax.broadcasted_iota(jnp.int32, (b, ccap), 0) * m
    src = (row_base + src_tile) * t + (p - src_off)
    valid = p < total[:, None]
    src = jnp.where(valid, src, 0).reshape(-1)
    ckw = tuple(
        jnp.where(valid, jnp.take(w.reshape(-1), src).reshape(b, ccap), _MAXU)
        for w in tkw
    )
    cv = jnp.where(valid, jnp.take(tv.reshape(-1), src).reshape(b, ccap),
                   _IMAX)

    fkw, fv = _sort_small_rows(ckw, cv, tplan)
    return tuple(w[:, :k] for w in fkw), fv[:, :k]


def topk_batched(x: jax.Array, k: int, cfg: SortConfig = DEFAULT_CONFIG):
    """Top-k (descending) values + column indices of every row of (B, C).

    Equivalent to ``jax.lax.top_k(x, k)`` (ties toward the smaller
    index) but via the partial deterministic sample sort, one launch for
    the whole batch — the serving shape: (batch, vocab) logits.

    Args:
        x: (B, C) scores in any codec dtype (see :func:`topk`).
        k: 1 <= k <= C.
        cfg: pipeline knobs (``descending`` ignored, see :func:`topk`;
            ``cfg.check`` enables runtime invariants + degradation).
    Returns:
        (values (B, k) in x.dtype, indices (B, k) int32).
    """
    assert x.ndim == 2, x.shape
    b, n = x.shape
    assert 1 <= k <= n
    if b == 0:
        return (jnp.zeros((0, k), x.dtype), jnp.zeros((0, k), jnp.int32))
    guard.validate_check(cfg.check)
    codec = codec_for(x.dtype, descending=True)
    kw = codec.encode(x)  # ascending canonical == descending score

    def run(tplan):
        if n <= tplan.direct_max:
            vals = jnp.broadcast_to(
                jnp.arange(n, dtype=jnp.int32)[None, :], (b, n)
            )
            fkw, fv = _sort_small_rows(kw, vals, tplan)
            fkw, fv = tuple(w[:, :k] for w in fkw), fv[:, :k]
        else:
            fkw, fv = _smallest_k_rows(kw, tplan)
        v, i = codec.decode(fkw), fv
        if cfg.check != "off":
            guard.check_topk(x, v, i, k, cfg.check, codec)
        return v, i

    tplan = build_topk_plan(n, k, x.dtype, cfg, rows=b)
    try:
        return run(tplan)
    except Exception as e1:
        alt = _fallback_topk_plan(n, k, x.dtype, tplan, rows=b)
        if alt is not None:
            guard.record_degradation(
                _topk_site(tplan), "fallback",
                f"impl={tplan.impl} topk plan", "default xla stand-in plan",
                e1)
            try:
                return run(alt)
            except Exception as e2:
                e1 = e2
        guard.record_degradation(
            _topk_site(tplan), "fallback",
            "partial-sort top-k", "jax.lax.top_k reference", e1)
        return _reference_topk(x, k, codec, cfg.check)
