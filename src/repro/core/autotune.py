"""Plan autotuner: analytically pruned, measured search over the
sort-plan space, with a persistent on-disk plan cache.

The planner (``core/plan.py``) makes the schedule explicit data; this
module picks the BEST schedule for a signature.  The knobs that
dominate throughput (``tile``, ``s``, ``block_rows``, the fusion
flags, the relocation mode, the local-sort strategy) must be tuned per
architecture and input size (Leischner et al.; Casanova et al.), and
the deterministic pipeline makes every candidate a pure config swap.

Search protocol (DESIGN.md §10): every candidate in the space is
scored by the analytic cost model (``core/cost_model.estimate``), and
only the ``measure_budget`` cheapest-predicted candidates are timed on
real executions — the base config (candidate 0) is always among them,
so the winner is never slower than the default schedule.
``measure_budget=None`` restores the exhaustive measured search.
Predicted and measured cost for EVERY candidate are recorded on
:class:`AutotuneResult` so model error is observable (the autotune
benchmark suite writes it into ``BENCH_sort.json``).

Cross-shape transfer: on a store miss at a new signature,
:func:`plan_for` seeds the measured set from the cached winner at the
NEAREST signature (same dtype/order/backend, nearest log2 n, then
log2 rows) and caps the budget at 2 measurements (base + transferred
winner) — warm workloads converge without a fresh search.

Cache semantics (DESIGN.md §7): plans are cached under
``(shape, dtype, backend, cfg-fingerprint)`` — the signature of the
*requesting* config (fingerprint over every field except ``plan``).  A
hit deserializes to a plan EQUAL to the one saved (dataclass equality,
tested), so the jit static-argument cache also hits: repeated
same-signature ``sort()`` calls after a plan-cache hit compile zero new
executables.

The cache lives at ``$REPRO_SORT_PLAN_CACHE`` (default
``~/.cache/repro_sort/plans.json``); writes are atomic
(tmp + ``os.replace``).  ``SortConfig(plan="autotune")`` routes every
public entry point through :func:`plan_for`; benchmarks record
best-found plans and their speedups via ``benchmarks/run.py --suite
autotune``.
"""

from __future__ import annotations

import dataclasses
import json
import os
import time
import warnings

import jax
import numpy as np

from repro.core import cost_model, faults, guard
from repro.core.plan import (
    ShardPlan,
    SortPlan,
    build_plan,
    build_shard_plan,
    plan_from_dict,
    plan_to_dict,
    shard_plan_from_dict,
    shard_plan_to_dict,
)
from repro.core.sort_config import SortConfig, next_pow2

_CACHE_ENV = "REPRO_SORT_PLAN_CACHE"
_STORE_SCHEMA = "sort_plan_cache/v1"

# Process-local memo so a warm signature never re-reads the disk store.
_MEMO: dict[str, SortPlan] = {}
# Memo for explicit plan FILES (SortConfig(plan=<path>)), keyed by
# (path, mtime_ns) so the hot serving path pays one stat() per call
# instead of open+parse+tree-rebuild, while an updated file still
# reloads.
_FILE_MEMO: dict[tuple, SortPlan] = {}


def cache_path() -> str:
    """Resolved plan-cache location (env override, else XDG-ish default)."""
    env = os.environ.get(_CACHE_ENV)
    if env:
        return env
    return os.path.join(
        os.path.expanduser("~"), ".cache", "repro_sort", "plans.json"
    )


def cache_key(plan: SortPlan) -> str:
    """The persistent-cache key: every component of the plan signature —
    (rows, length) shape, dtype+order, resolved impl/interpret/backend,
    and the requesting config's fingerprint."""
    return "|".join(str(x) for x in plan.signature())


def _fresh_store() -> dict:
    return {"schema": _STORE_SCHEMA, "plans": {}, "denylist": {}}


def _quarantine_store(path: str, err: Exception) -> None:
    """Corrupt store recovery (DESIGN.md §11): atomically rename the
    unparseable file to ``<path>.corrupt-<pid>`` — NEVER overwrite it
    in place (the evidence survives, and the next save rebuilds a clean
    store) — and warn once."""
    qpath = f"{path}.corrupt-{os.getpid()}"
    try:
        os.replace(path, qpath)
    except OSError:
        qpath = "<rename failed; left in place>"
    warnings.warn(
        f"plan cache {path} is corrupt ({type(err).__name__}: {err}); "
        f"quarantined to {qpath} and rebuilding a clean store",
        guard.DegradationWarning,
        stacklevel=3,
    )


def _load_store(path: str) -> dict:
    """Read the JSON plan store; degrade to an empty store on any
    failure (degradation chain: a broken cache must never break a
    sort).  Corrupt JSON is quarantined (atomic rename) so the bytes
    survive for inspection; unreadable files (I/O errors, injected
    ``cache.load`` faults) warn and fall back without quarantine."""
    try:
        faults.check("cache.load")
        with open(path) as f:
            store = json.load(f)
    except FileNotFoundError:
        return _fresh_store()
    except json.JSONDecodeError as e:
        _quarantine_store(path, e)
        return _fresh_store()
    except (faults.FaultInjected, OSError) as e:
        warnings.warn(
            f"plan cache {path} unreadable ({type(e).__name__}: {e}); "
            f"continuing with an empty store",
            guard.DegradationWarning,
            stacklevel=2,
        )
        return _fresh_store()
    if store.get("schema") != _STORE_SCHEMA:
        return _fresh_store()
    store.setdefault("denylist", {})
    return store


def _save_store(path: str, store: dict) -> None:
    faults.check("cache.save")
    d = os.path.dirname(path)
    if d:
        os.makedirs(d, exist_ok=True)
    tmp = f"{path}.tmp.{os.getpid()}"
    with open(tmp, "w") as f:
        json.dump(store, f, indent=1, sort_keys=True)
        f.write("\n")
    os.replace(tmp, path)


def _persist_store(path: str, store: dict) -> None:
    """Best-effort store persist for the tune-and-cache paths: a failed
    save (I/O error, injected ``cache.save`` fault) degrades to
    memo-only caching — the tuned plan is still returned and memoized,
    only the cross-process record is lost (recorded + warned)."""
    try:
        _save_store(path, store)
    except (faults.FaultInjected, OSError) as e:
        guard.record_degradation(
            "cache.save", "fallback", f"persist to {path}",
            "process-memo only (store not written)", e)


def save_plan(plan: SortPlan, path: str, *, meta: dict | None = None) -> None:
    """Write one plan to ``path`` as a standalone plan file (the format
    ``SortConfig(plan=<path>)`` and :func:`load_plan` read)."""
    d = os.path.dirname(path)
    if d:
        os.makedirs(d, exist_ok=True)
    payload = plan_to_dict(plan)
    if meta:
        payload["meta"] = meta
    tmp = f"{path}.tmp.{os.getpid()}"
    with open(tmp, "w") as f:
        json.dump(payload, f, indent=1, sort_keys=True)
        f.write("\n")
    os.replace(tmp, path)


def load_plan(
    path: str,
    *,
    length: int | None = None,
    dtype=None,
    cfg: SortConfig | None = None,
    rows: int = 1,
    pad_rows: bool = False,
) -> SortPlan:
    """Read a plan file saved by :func:`save_plan`.

    When a call signature is supplied (``length``/``dtype``/``rows``,
    as ``resolve_plan`` does for ``SortConfig(plan=<path>)``), the
    file's plan must match it — shape, dtype and order are load-bearing
    (ValueError otherwise).  The plan's tunables (tile, s, ...) override
    the requesting cfg's: that is the point of carrying a tuned plan.
    """
    import jax.numpy as jnp

    fkey = (path, os.stat(path).st_mtime_ns)
    plan = _FILE_MEMO.get(fkey)
    if plan is None:
        with open(path) as f:
            d = json.load(f)
        d.pop("meta", None)
        plan = plan_from_dict(d)
        _FILE_MEMO[fkey] = plan
    if length is not None:
        want = (rows, length, jnp.dtype(dtype).name,
                cfg.descending if cfg else plan.descending)
        got = (plan.rows, plan.length, plan.dtype_name, plan.descending)
        if want != got:
            raise ValueError(
                f"plan file {path} was built for (rows, length, dtype, "
                f"descending)={got}, call needs {want}"
            )
    return plan


# ----------------------------------------------------------------------
# Candidate space
# ----------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Candidate:
    """One point of the search space (a full SortConfig swap)."""

    cfg: SortConfig
    label: str


def candidate_space(
    cfg: SortConfig, length: int, *, max_trials: int = 16
) -> list[Candidate]:
    """Deterministic, ordered candidate list around ``cfg``.

    The BASE config is always candidate 0, so the measured winner is by
    construction at least as fast as the default plan.  The space
    crosses strategy × tile × s × block_rows × fusion × relocation,
    nearest neighbours first, deduplicated, truncated to
    ``max_trials``.  The local-sort strategies (DESIGN.md §8) come
    right after the base config: they are the highest-variance axis
    (radix vs merge vs bitonic differ by integer factors across key
    widths and input distributions).
    """
    tiles = [cfg.tile, cfg.tile * 2, max(cfg.tile // 2, 128), cfg.tile * 4]
    svals = [cfg.s, cfg.s * 2, max(cfg.s // 2, 2), cfg.s * 4]
    brs = [cfg.block_rows, 8, 32] if cfg.block_rows is None else [
        cfg.block_rows, None, 8
    ]
    fusions = [(True, True), (False, False)]
    relocs = ["gather", "scatter"]
    if cfg.relocation != "gather":
        relocs.reverse()
    if not cfg.fuse_sampling:
        fusions.reverse()

    seen: set[SortConfig] = set()
    out: list[Candidate] = []

    def _add(**kw):
        if len(out) >= max_trials:
            return
        t = kw.get("tile", cfg.tile)
        s = kw.get("s", cfg.s)
        if s > t or t % s != 0 or t > max(next_pow2(length), 128):
            return
        # Only grow direct_max when a LARGER tile needs it to stay a
        # valid config — candidate 0 (no overrides) must be the
        # requesting config itself, bit for bit, or default_us/speedup
        # would measure the wrong schedule.
        if t > cfg.direct_max:
            kw.setdefault("direct_max", 2 * t)
        kw.setdefault("plan", "default")
        try:
            cand = dataclasses.replace(cfg, **kw)
        except ValueError:
            return
        if cand in seen:
            return
        seen.add(cand)
        bits = ",".join(f"{k}={v}" for k, v in sorted(kw.items())
                        if k not in ("direct_max", "plan"))
        out.append(Candidate(cfg=cand, label=bits or "base"))

    _add()  # the base config: candidate 0, the speedup reference
    for st in ("bitonic", "radix", "merge"):
        if st != cfg.strategy:
            _add(strategy=st)
    for t in tiles:
        _add(tile=t)
    for s in svals:
        _add(s=s)
    for t in tiles[:2]:
        for s in svals[:2]:
            _add(tile=t, s=s)
    for br in brs:
        _add(block_rows=br)
    for fs, fr in fusions:
        _add(fuse_sampling=fs, fuse_ranking=fr)
    for rl in relocs:
        _add(relocation=rl)
    return out


# ----------------------------------------------------------------------
# Measurement
# ----------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TrialResult:
    label: str
    us_per_call: float


@dataclasses.dataclass(frozen=True)
class CandidateScore:
    """Predicted (and, when measured, observed) cost of one candidate.

    One of these exists for EVERY candidate in the search space, not
    just the measured ones — model error (predicted rank vs measured
    rank) is observable from a single :class:`AutotuneResult`.

    Attributes:
        index: position in the candidate space (0 = base config).
        label: the candidate's config-delta label.
        predicted: analytic cost (HBM byte-equivalents,
            ``cost_model.estimate(...).total``).
        us_per_call: median measured micros, or None if the candidate
            was pruned by the measure budget (or failed to run).
    """

    index: int
    label: str
    predicted: float
    us_per_call: float | None = None


@dataclasses.dataclass(frozen=True)
class AutotuneResult:
    """Outcome of one tuning run.

    Attributes:
        best_plan: the measured-fastest candidate's plan.
        best_us / default_us: median wall micros of the winner and of
            candidate 0 (the requesting config) — ``speedup`` is their
            ratio, >= 1.0 up to timer noise since the default is in the
            space.
        trials: every MEASURED candidate, in candidate order (the base
            config is always measured, so ``trials[0]`` is "base").
        candidates: predicted vs measured for every candidate in the
            space, candidate order (measured ones carry
            ``us_per_call``).
        measure_budget: the budget the run used (None = exhaustive).
        cost_model_version: ``cost_model.COST_MODEL_VERSION`` at tune
            time (persisted; a bump invalidates cached records).
        failed: (label, error) for every candidate that exhausted the
            measurement retry chain this run — ``plan_for`` persists
            these into the store's per-signature denylist.
        skipped: labels excluded up front by the caller's denylist.
    """

    best_plan: SortPlan
    best_label: str
    best_us: float
    default_us: float
    trials: tuple[TrialResult, ...]
    candidates: tuple[CandidateScore, ...] = ()
    measure_budget: int | None = None
    cost_model_version: str = cost_model.COST_MODEL_VERSION
    failed: tuple[tuple[str, str], ...] = ()
    skipped: tuple[str, ...] = ()

    @property
    def speedup(self) -> float:
        return self.default_us / self.best_us if self.best_us else 1.0


def _validate_budget(measure_budget) -> None:
    if measure_budget is None:
        return
    if not isinstance(measure_budget, int) or isinstance(
        measure_budget, bool
    ) or measure_budget < 1:
        raise ValueError(
            f"measure_budget must be an int >= 1 (candidates to time) or "
            f"None for the exhaustive measured search, got "
            f"{measure_budget!r}"
        )


def _select_measured(
    predicted: list[float],
    measure_budget: int | None,
    mandatory: list[int],
) -> list[int]:
    """Indices to time: the mandatory set (base config, transfer
    seeds), then cheapest-predicted-first up to the budget.  Ties on
    predicted cost break deterministically toward the lower candidate
    index, so equal-cost reruns measure the same set."""
    if measure_budget is None:
        return list(range(len(predicted)))
    chosen = list(dict.fromkeys(mandatory))
    ranked = sorted(range(len(predicted)), key=lambda i: (predicted[i], i))
    for i in ranked:
        if len(chosen) >= measure_budget:
            break
        if i not in chosen:
            chosen.append(i)
    return sorted(chosen)


def _measure(fn, x, *, repeats: int, warmup: int = 1) -> float:
    faults.check("autotune.measure")
    for _ in range(warmup):
        jax.block_until_ready(fn(x))
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(x))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts)) * 1e6


# Retry policy for candidate measurement (DESIGN.md §11): transient
# launch/measurement failures get _MEASURE_ATTEMPTS total tries with
# exponential backoff from _MEASURE_BASE_DELAY seconds; a candidate
# that exhausts them is reported on ``AutotuneResult.failed`` and
# (via plan_for/shard_plan_for) lands in the store's per-signature
# denylist so later tuning runs skip it outright.
_MEASURE_ATTEMPTS = 3
_MEASURE_BASE_DELAY = 0.02


def _measure_candidate(fn, x, label: str, *, repeats: int,
                       warmup: int = 1) -> tuple[float | None, str | None]:
    """One candidate's guarded measurement: bounded retry with
    exponential backoff, then (None, error-string) — the caller
    denylists, never silently swallows."""
    try:
        us = guard.with_retries(
            lambda: _measure(fn, x, repeats=repeats, warmup=warmup),
            site=f"autotune.measure[{label}]",
            attempts=_MEASURE_ATTEMPTS,
            base_delay=_MEASURE_BASE_DELAY,
        )
        return us, None
    except Exception as e:  # terminal after retries: report, denylist
        warnings.warn(
            f"autotune candidate {label!r} failed to measure after "
            f"{_MEASURE_ATTEMPTS} attempts ({type(e).__name__}: {e}); "
            f"excluded from this run and denylisted for the signature",
            guard.DegradationWarning,
            stacklevel=2,
        )
        return None, f"{type(e).__name__}: {e}"


def _sample_input(length: int, dtype, rows: int, seed: int):
    """Deterministic representative data for measurement (seeded uniform
    keys of the target dtype), shared by the single-device and
    distributed tuners."""
    import jax.numpy as jnp

    rng = np.random.default_rng(seed)
    npdt = np.dtype(jnp.dtype(dtype).name)
    shape = (length,) if rows == 1 else (rows, length)
    if npdt.kind == "f":
        x = rng.standard_normal(shape).astype(npdt)
    elif npdt.kind == "b":
        x = rng.integers(0, 2, shape).astype(npdt)
    elif npdt.kind == "u":
        x = rng.integers(0, np.iinfo(npdt).max, shape, dtype=np.uint64).astype(npdt)
    else:
        info = np.iinfo(npdt)
        x = rng.integers(info.min, info.max, shape, dtype=np.int64).astype(npdt)
    return jnp.asarray(x)


def autotune(
    length: int,
    dtype,
    cfg: SortConfig,
    *,
    rows: int = 1,
    pad_rows: bool = False,
    max_trials: int = 16,
    repeats: int = 3,
    warmup: int = 1,
    seed: int = 0,
    measure_budget: int | None = 5,
    priors: cost_model.Priors | None = None,
    seed_cfgs: tuple[SortConfig, ...] = (),
    denylist: frozenset[str] = frozenset(),
) -> AutotuneResult:
    """Budgeted search: score every candidate's plan with the analytic
    cost model, time only the ``measure_budget`` cheapest-predicted
    candidates (base config always included) on representative data,
    return the measured winner.

    Args:
        measure_budget: candidates to actually time (None =
            exhaustive).  ValueError if not a positive int or None.
        priors: distribution priors for the cost model (sortedness,
            top-bits entropy — see ``core.probe.priors_for``); None
            uses the uniform-random defaults.
        seed_cfgs: extra configs appended to the candidate space and
            FORCED into the measured set (the cross-shape transfer
            path of :func:`plan_for` passes the nearest cached
            winner's config here).
        denylist: candidate labels never to measure (persisted failures
            from earlier runs at this signature — see :func:`plan_for`).

    Data is deterministic (seeded uniform keys of the target dtype), so
    back-to-back runs rank candidates consistently up to timer noise;
    ties on predicted cost break toward the lower candidate index.
    Candidates whose measurement exhausts the retry chain are reported
    on ``result.failed`` (and excluded from the winner), never silently
    swallowed.  Raises :class:`guard.SortRuntimeError` when NO candidate
    measures successfully.
    """
    from repro.core import bucket_sort

    _validate_budget(measure_budget)
    xj = _sample_input(length, dtype, rows, seed)

    cands = candidate_space(cfg, length, max_trials=max_trials)
    mandatory = [0]
    seen_cfgs = {c.cfg for c in cands}
    for sc in seed_cfgs:
        sc = dataclasses.replace(sc, plan="default")
        if sc in seen_cfgs:
            mandatory.append(
                next(i for i, c in enumerate(cands) if c.cfg == sc)
            )
            continue
        seen_cfgs.add(sc)
        cands.append(Candidate(cfg=sc, label="transfer"))
        mandatory.append(len(cands) - 1)

    plans: list[SortPlan] = []
    predicted: list[float] = []
    for cand in cands:
        plan = build_plan(
            length, dtype, cand.cfg, rows=rows, pad_rows=pad_rows
        )
        plans.append(plan)
        try:
            predicted.append(cost_model.estimate(plan, priors=priors).total)
        except Exception as e:  # score as worst — never silently
            warnings.warn(
                f"cost model failed for candidate {cand.label!r} "
                f"({type(e).__name__}: {e}); scoring as +inf",
                guard.DegradationWarning, stacklevel=2)
            predicted.append(float("inf"))

    measured = set(_select_measured(predicted, measure_budget, mandatory))
    skipped = tuple(
        c.label for i, c in enumerate(cands)
        if i in measured and c.label in denylist
    )
    measured -= {i for i, c in enumerate(cands) if c.label in denylist}
    trials: list[TrialResult] = []
    scores: list[CandidateScore] = []
    failed: list[tuple[str, str]] = []
    best_plan, best_label = None, ""
    best_us, default_us = float("inf"), float("inf")
    for i, cand in enumerate(cands):
        us = None
        if i in measured:
            us, err = _measure_candidate(
                lambda a, p=plans[i]: bucket_sort.sort_planned(a, p),
                xj, cand.label, repeats=repeats, warmup=warmup,
            )
            if err is not None:
                failed.append((cand.label, err))
        scores.append(CandidateScore(
            index=i, label=cand.label, predicted=predicted[i],
            us_per_call=us,
        ))
        if us is None:
            continue
        trials.append(TrialResult(label=cand.label, us_per_call=us))
        if i == 0:
            default_us = us
        if us < best_us:
            best_plan, best_label, best_us = plans[i], cand.label, us
    if best_plan is None:
        raise guard.SortRuntimeError(
            "autotune.measure", "at least one candidate measured",
            f"all {len(measured)} measured candidate(s) failed "
            f"({len(skipped)} denylisted) for length={length} rows={rows}")
    return AutotuneResult(
        best_plan=best_plan,
        best_label=best_label,
        best_us=best_us,
        default_us=default_us,
        trials=tuple(trials),
        candidates=tuple(scores),
        measure_budget=measure_budget,
        failed=tuple(failed),
        skipped=skipped,
    )


# ----------------------------------------------------------------------
# The cfg.plan == "autotune" entry: cache-or-tune (with cross-shape
# transfer seeding on a miss)
# ----------------------------------------------------------------------


def _record_is_current(rec: dict | None) -> bool:
    """A persisted record is usable only if it was tuned under the
    CURRENT cost-model version — a version bump means the analytic
    pruning that picked the winner is no longer trusted, so the record
    is a clean miss that re-tunes (mirrors the shard_plan/v1
    schema-bump behavior)."""
    return (
        rec is not None
        and rec.get("cost_model") == cost_model.COST_MODEL_VERSION
    )


def _cfg_from_winner_plan(plan: SortPlan, cfg: SortConfig):
    """Reconstruct a tunable config from a cached winner plan's root
    level, applied over the requesting ``cfg`` (the transfer seed).
    None when the winner's geometry can't express a valid config."""
    node = plan.root
    kw: dict = dict(
        plan="default",
        block_rows=node.block_rows,
        strategy=node.strategy,
        radix_bits=node.radix_bits,
        merge_run=node.merge_run,
    )
    if node.kind == "bucket":
        kw.update(
            tile=node.tile,
            s=node.s,
            fuse_sampling=node.fuse_sampling,
            fuse_ranking=node.fuse_ranking,
            relocation=node.relocation,
        )
        if node.tile > cfg.direct_max:
            kw["direct_max"] = 2 * node.tile
    try:
        return dataclasses.replace(cfg, **kw)
    except ValueError:
        return None


def _nearest_plan_record(
    store: dict, base: SortPlan, key: str
) -> tuple[SortPlan, str] | None:
    """The cached winner at the signature NEAREST to ``base``: same
    dtype/order/backend triple required, then prefer the same config
    fingerprint, then the closest log2 length, then log2 rows (ties
    break on the store key, so the choice is deterministic)."""
    want = (base.dtype_name, str(base.descending), base.impl,
            str(base.interpret), base.backend)
    best = None
    for k, rec in store["plans"].items():
        if k == key or k.startswith("shard|"):
            continue
        if not _record_is_current(rec):
            continue
        parts = k.split("|")
        if len(parts) != 8 or tuple(parts[2:7]) != want:
            continue
        try:
            rows_k, length_k = int(parts[0]), int(parts[1])
            plan = plan_from_dict(rec["plan"])
        except (ValueError, TypeError, KeyError):
            continue
        dist = (
            0 if parts[7] == base.cfg_fingerprint else 1,
            abs(np.log2(max(length_k, 1)) - np.log2(max(base.length, 1))),
            abs(np.log2(max(rows_k, 1)) - np.log2(max(base.rows, 1))),
            k,
        )
        if best is None or dist < best[0]:
            best = (dist, plan, k)
    return (best[1], best[2]) if best else None


def plan_for(
    length: int,
    dtype,
    cfg: SortConfig,
    *,
    rows: int = 1,
    pad_rows: bool = False,
    path: str | None = None,
    max_trials: int = 16,
    repeats: int = 3,
    measure_budget: int | None = 5,
    priors: cost_model.Priors | None = None,
    transfer: bool = True,
) -> SortPlan:
    """Cached-or-tuned plan for a signature (the ``plan="autotune"``
    path).

    Lookup order: process memo -> on-disk store -> run
    :func:`autotune` and persist the winner.  The reloaded plan is
    EQUAL to the saved one, so jit's static-argument cache hits too —
    a plan-cache hit performs zero retraces (tested).

    Persisted records carry the cost-model version; a record tuned
    under a stale version is a clean miss that re-tunes.  On a miss
    with ``transfer=True`` (default), the measured set is seeded from
    the cached winner at the nearest signature and the budget drops to
    ≤2 measurements (base + transferred winner).
    """
    base = build_plan(length, dtype, cfg, rows=rows, pad_rows=pad_rows)
    key = cache_key(base)
    if key in _MEMO:
        return _MEMO[key]
    path = path or cache_path()
    store = _load_store(path)
    rec = store["plans"].get(key)
    if rec is not None and _record_is_current(rec):
        try:
            plan = plan_from_dict(rec["plan"])
        except (ValueError, TypeError):
            # A record from an older plan schema (e.g. pre-strategy
            # sort_plan/v1): treat as a clean miss — re-tune below and
            # overwrite, never misread a stale plan.
            pass
        else:
            _MEMO[key] = plan
            return plan

    seed_cfgs: tuple[SortConfig, ...] = ()
    budget = measure_budget
    transfer_from = None
    if transfer and measure_budget is not None:
        near = _nearest_plan_record(store, base, key)
        if near is not None:
            seed_cfg = _cfg_from_winner_plan(near[0], cfg)
            if seed_cfg is not None:
                seed_cfgs = (seed_cfg,)
                budget = min(measure_budget, 2)
                transfer_from = near[1]

    deny = store.get("denylist", {}).get(key, {})
    result = autotune(
        length, dtype, cfg, rows=rows, pad_rows=pad_rows,
        max_trials=max_trials, repeats=repeats,
        measure_budget=budget, priors=priors, seed_cfgs=seed_cfgs,
        denylist=frozenset(deny),
    )
    if result.failed:
        store.setdefault("denylist", {}).setdefault(key, {}).update(
            dict(result.failed))
    store["plans"][key] = dict(
        plan=plan_to_dict(result.best_plan),
        best_us=round(result.best_us, 1),
        default_us=round(result.default_us, 1),
        speedup=round(result.speedup, 3),
        cost_model=result.cost_model_version,
        measure_budget=result.measure_budget,
        measured=sum(
            1 for c in result.candidates if c.us_per_call is not None
        ),
        candidates=len(result.candidates),
        **({"transfer_from": transfer_from} if transfer_from else {}),
    )
    _persist_store(path, store)
    _MEMO[key] = result.best_plan
    return result.best_plan


# ----------------------------------------------------------------------
# Distributed candidate axis: oversample x local strategy x exchange
# tiling, persisted in the same JSON store keyed by mesh signature
# ----------------------------------------------------------------------

# Process-local memo for tuned shard plans (same role as _MEMO).
_SHARD_MEMO: dict[str, ShardPlan] = {}


def shard_cache_key(plan: ShardPlan) -> str:
    """The persistent-cache key of a distributed plan: the ``shard|``
    namespace plus every component of :meth:`ShardPlan.signature` —
    mesh signature (axis names + D), shard shape, dtype+order, the
    requested oversample/pair_align, the resolved backend triple, and
    the requesting config's fingerprint.  Lives in the same JSON store
    as the single-device keys (disjoint namespaces)."""
    return "shard|" + "|".join(str(x) for x in plan.signature())


@dataclasses.dataclass(frozen=True)
class ShardCandidate:
    """One point of the distributed search space."""

    cfg: SortConfig
    oversample: int
    pair_align: int
    label: str


def shard_candidate_space(
    cfg: SortConfig,
    *,
    oversample: int = 8,
    pair_align: int = 8,
    max_trials: int = 8,
) -> list[ShardCandidate]:
    """Deterministic, ordered distributed candidate list.

    The BASE (requested cfg/oversample/pair_align) is candidate 0, so
    the measured winner is never slower than the default schedule.  The
    axes, nearest first: the per-phase local-sort strategy (highest
    variance, DESIGN.md §8), the oversample factor c (trades sample
    volume against the 1/c slack in ``c_pair``), and the exchange
    tiling ``pair_align`` (lane alignment of the per-pair all_to_all
    capacity).
    """
    seen: set[tuple] = set()
    out: list[ShardCandidate] = []

    def _add(label: str, *, strategy=None, osamp=None, palign=None):
        if len(out) >= max_trials:
            return
        o = oversample if osamp is None else osamp
        pa = pair_align if palign is None else palign
        if o < 1 or o & (o - 1) or pa < 8 or pa & (pa - 1):
            return
        try:
            cand_cfg = dataclasses.replace(
                cfg, plan="default",
                **({"strategy": strategy} if strategy else {}),
            )
        except ValueError:
            return
        key = (cand_cfg, o, pa)
        if key in seen:
            return
        seen.add(key)
        out.append(ShardCandidate(
            cfg=cand_cfg, oversample=o, pair_align=pa, label=label
        ))

    _add("base")
    for st in ("bitonic", "radix", "merge"):
        if st != cfg.strategy:
            _add(f"strategy={st}", strategy=st)
    for o in (oversample * 2, max(oversample // 2, 1), oversample * 4):
        _add(f"oversample={o}", osamp=o)
    for pa in (128, 256):
        _add(f"pair_align={pa}", palign=pa)
    return out


def autotune_shard(
    mesh,
    axis,
    n_global: int,
    dtype,
    cfg: SortConfig,
    *,
    oversample: int = 8,
    pair_align: int = 8,
    max_trials: int = 8,
    repeats: int = 2,
    warmup: int = 1,
    seed: int = 0,
    measure_budget: int | None = 5,
    priors: cost_model.Priors | None = None,
    seed_candidates: tuple[ShardCandidate, ...] = (),
    denylist: frozenset[str] = frozenset(),
) -> AutotuneResult:
    """Budgeted search over the distributed schedule space: score each
    candidate's :class:`ShardPlan` analytically (including the
    ``c_pair``-padded collective volume), time only the
    ``measure_budget`` cheapest-predicted candidates (base always
    included) on the real jit'd distributed executor over ``mesh``,
    return the measured winner.

    Needs a mesh whose ``axis`` spans >= 2 devices (forced-host meshes
    in tests/benchmarks); data is deterministic so back-to-back runs
    rank candidates consistently up to timer noise.
    """
    from repro.core import distributed_sort

    _validate_budget(measure_budget)
    axt = (axis,) if isinstance(axis, str) else tuple(axis)
    d = 1
    for a in axt:
        d *= mesh.shape[a]
    xj = _sample_input(n_global, dtype, 1, seed)

    space = shard_candidate_space(
        cfg, oversample=oversample, pair_align=pair_align,
        max_trials=max_trials,
    )
    mandatory = [0]
    seen = {(c.cfg, c.oversample, c.pair_align) for c in space}
    for sc in seed_candidates:
        k = (sc.cfg, sc.oversample, sc.pair_align)
        if k in seen:
            mandatory.append(next(
                i for i, c in enumerate(space)
                if (c.cfg, c.oversample, c.pair_align) == k
            ))
            continue
        seen.add(k)
        space.append(sc)
        mandatory.append(len(space) - 1)

    plans: list[ShardPlan] = []
    predicted: list[float] = []
    for cand in space:
        plan = build_shard_plan(
            axt, d, n_global // d, dtype, cand.cfg,
            oversample=cand.oversample, pair_align=cand.pair_align,
        )
        plans.append(plan)
        try:
            predicted.append(cost_model.estimate(plan, priors=priors).total)
        except Exception as e:  # score as worst — never silently
            warnings.warn(
                f"cost model failed for distributed candidate "
                f"{cand.label!r} ({type(e).__name__}: {e}); scoring as +inf",
                guard.DegradationWarning, stacklevel=2)
            predicted.append(float("inf"))

    measured = set(_select_measured(predicted, measure_budget, mandatory))
    skipped = tuple(
        c.label for i, c in enumerate(space)
        if i in measured and c.label in denylist
    )
    measured -= {i for i, c in enumerate(space) if c.label in denylist}
    trials: list[TrialResult] = []
    scores: list[CandidateScore] = []
    failed: list[tuple[str, str]] = []
    best_plan, best_label = None, ""
    best_us, default_us = float("inf"), float("inf")
    for i, cand in enumerate(space):
        us = None
        if i in measured:
            us, err = _measure_candidate(
                lambda a, p=plans[i]: distributed_sort._sharded_argsort(
                    a, mesh, p
                ),
                xj, cand.label, repeats=repeats, warmup=warmup,
            )
            if err is not None:
                failed.append((cand.label, err))
        scores.append(CandidateScore(
            index=i, label=cand.label, predicted=predicted[i],
            us_per_call=us,
        ))
        if us is None:
            continue
        trials.append(TrialResult(label=cand.label, us_per_call=us))
        if i == 0:
            default_us = us
        if us < best_us:
            best_plan, best_label, best_us = plans[i], cand.label, us
    if best_plan is None:
        raise guard.SortRuntimeError(
            "autotune.measure", "at least one candidate measured",
            f"all {len(measured)} measured distributed candidate(s) "
            f"failed ({len(skipped)} denylisted) for n_global={n_global} "
            f"D={d}")
    return AutotuneResult(
        best_plan=best_plan,
        best_label=best_label,
        best_us=best_us,
        default_us=default_us,
        trials=tuple(trials),
        candidates=tuple(scores),
        measure_budget=measure_budget,
        failed=tuple(failed),
        skipped=skipped,
    )


def _nearest_shard_record(
    store: dict, base: ShardPlan, key: str
) -> tuple[ShardPlan, str] | None:
    """The cached distributed winner at the mesh signature NEAREST to
    ``base``: same dtype/order/backend triple required, then prefer
    the same config fingerprint, then the closest log2 shard length,
    then log2 D (deterministic key tie-break)."""
    want = (base.dtype_name, str(base.descending), base.impl,
            str(base.interpret), base.backend)
    best = None
    for k, rec in store["plans"].items():
        if k == key or not k.startswith("shard|"):
            continue
        if not _record_is_current(rec):
            continue
        parts = k.split("|")[1:]
        if len(parts) != 11 or (
            tuple(parts[3:5]) + tuple(parts[7:10])
        ) != want:
            continue
        try:
            d_k, n_local_k = int(parts[1]), int(parts[2])
            plan = shard_plan_from_dict(rec["plan"])
        except (ValueError, TypeError, KeyError):
            continue
        dist = (
            0 if parts[10] == base.cfg_fingerprint else 1,
            abs(np.log2(max(n_local_k, 1))
                - np.log2(max(base.n_local, 1))),
            abs(np.log2(max(d_k, 1)) - np.log2(max(base.d, 1))),
            k,
        )
        if best is None or dist < best[0]:
            best = (dist, plan, k)
    return (best[1], best[2]) if best else None


def _shard_seed_from_record(plan: ShardPlan, cfg: SortConfig):
    """Transfer seed for the distributed search: the cached winner's
    oversample/pair_align plus its run-phase local-sort strategy,
    applied over the requesting ``cfg``."""
    node = plan.run_plan.root
    try:
        seed_cfg = dataclasses.replace(
            cfg, plan="default", strategy=node.strategy,
            radix_bits=node.radix_bits, merge_run=node.merge_run,
        )
    except ValueError:
        return None
    return ShardCandidate(
        cfg=seed_cfg, oversample=plan.oversample,
        pair_align=plan.pair_align, label="transfer",
    )


def shard_plan_for(
    mesh,
    axis,
    n_global: int,
    dtype,
    cfg: SortConfig,
    *,
    oversample: int = 8,
    pair_align: int = 8,
    path: str | None = None,
    max_trials: int = 8,
    repeats: int = 2,
    measure_budget: int | None = 5,
    priors: cost_model.Priors | None = None,
    transfer: bool = True,
) -> ShardPlan:
    """Cached-or-tuned distributed plan (the ``plan="autotune"`` path of
    ``make_sharded_sort``).

    Lookup order mirrors :func:`plan_for`: process memo -> on-disk
    store (keyed by :func:`shard_cache_key`, i.e. by mesh signature) ->
    run :func:`autotune_shard` and persist the winner.  A reloaded plan
    is EQUAL to the saved one, so the distributed jit entry's static-arg
    cache hits too — a shard-plan-cache hit performs zero retraces
    (tested on forced-host meshes).

    Records carry the cost-model version (stale version = clean miss),
    and a miss with ``transfer=True`` seeds from the nearest cached
    mesh signature with the budget capped at 2 measurements, exactly
    as :func:`plan_for` does for the local path.
    """
    axt = (axis,) if isinstance(axis, str) else tuple(axis)
    d = 1
    for a in axt:
        d *= mesh.shape[a]
    base = build_shard_plan(
        axt, d, n_global // d, dtype, cfg,
        oversample=oversample, pair_align=pair_align,
    )
    key = shard_cache_key(base)
    if key in _SHARD_MEMO:
        return _SHARD_MEMO[key]
    path = path or cache_path()
    store = _load_store(path)
    rec = store["plans"].get(key)
    if rec is not None and _record_is_current(rec):
        try:
            plan = shard_plan_from_dict(rec["plan"])
        except (ValueError, TypeError):
            pass  # stale schema: clean miss, re-tune and overwrite
        else:
            _SHARD_MEMO[key] = plan
            return plan

    seeds: tuple[ShardCandidate, ...] = ()
    budget = measure_budget
    transfer_from = None
    if transfer and measure_budget is not None:
        near = _nearest_shard_record(store, base, key)
        if near is not None:
            seed = _shard_seed_from_record(near[0], cfg)
            if seed is not None:
                seeds = (seed,)
                budget = min(measure_budget, 2)
                transfer_from = near[1]

    deny = store.get("denylist", {}).get(key, {})
    result = autotune_shard(
        mesh, axt, n_global, dtype, cfg,
        oversample=oversample, pair_align=pair_align,
        max_trials=max_trials, repeats=repeats,
        measure_budget=budget, priors=priors, seed_candidates=seeds,
        denylist=frozenset(deny),
    )
    if result.failed:
        store.setdefault("denylist", {}).setdefault(key, {}).update(
            dict(result.failed))
    store["plans"][key] = dict(
        plan=shard_plan_to_dict(result.best_plan),
        best_us=round(result.best_us, 1),
        default_us=round(result.default_us, 1),
        speedup=round(result.speedup, 3),
        cost_model=result.cost_model_version,
        measure_budget=result.measure_budget,
        measured=sum(
            1 for c in result.candidates if c.us_per_call is not None
        ),
        candidates=len(result.candidates),
        **({"transfer_from": transfer_from} if transfer_from else {}),
    )
    _persist_store(path, store)
    _SHARD_MEMO[key] = result.best_plan
    return result.best_plan


def save_shard_plan(
    plan: ShardPlan, path: str, *, meta: dict | None = None
) -> None:
    """Write one distributed plan to ``path`` as a standalone file (the
    format ``SortConfig(plan=<path>)`` reads through
    ``make_sharded_sort`` and :func:`load_shard_plan`)."""
    d = os.path.dirname(path)
    if d:
        os.makedirs(d, exist_ok=True)
    payload = shard_plan_to_dict(plan)
    if meta:
        payload["meta"] = meta
    tmp = f"{path}.tmp.{os.getpid()}"
    with open(tmp, "w") as f:
        json.dump(payload, f, indent=1, sort_keys=True)
        f.write("\n")
    os.replace(tmp, path)


def load_shard_plan(
    path: str,
    *,
    axis=None,
    d: int | None = None,
    n_local: int | None = None,
    dtype=None,
    cfg: SortConfig | None = None,
) -> ShardPlan:
    """Read a distributed plan file saved by :func:`save_shard_plan`.

    When a call signature is supplied (as ``make_sharded_sort`` does
    for ``SortConfig(plan=<path>)``), the file's plan must match it —
    mesh axis/D, shard length, dtype and order are load-bearing
    (ValueError otherwise).
    """
    import jax.numpy as jnp

    fkey = (path, os.stat(path).st_mtime_ns)
    plan = _FILE_MEMO.get(fkey)
    if not isinstance(plan, ShardPlan):
        with open(path) as f:
            rec = json.load(f)
        rec.pop("meta", None)
        plan = shard_plan_from_dict(rec)
        _FILE_MEMO[fkey] = plan
    if d is not None:
        axt = (axis,) if isinstance(axis, str) else tuple(axis)
        want = (axt, d, n_local, jnp.dtype(dtype).name,
                cfg.descending if cfg else plan.descending)
        got = (plan.axis, plan.d, plan.n_local, plan.dtype_name,
               plan.descending)
        if want != got:
            raise ValueError(
                f"shard plan file {path} was built for (axis, d, n_local, "
                f"dtype, descending)={got}, call needs {want}"
            )
    return plan


def clear_memo() -> None:
    """Drop the process-local memos (tests use this to force the disk
    path)."""
    _MEMO.clear()
    _SHARD_MEMO.clear()
    _FILE_MEMO.clear()
