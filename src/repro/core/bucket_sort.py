"""GPU BUCKET SORT (Dehne & Zaboli 2010, Algorithm 1) — TPU-native, static shapes.

Single-device deterministic sample sort.  The paper's nine steps map to
(full file:symbol table in docs/paper_map.md):

  step 1  split into tiles            -> reshape (rows, L) -> (rows*m, T)
  step 2  local sort per SM           -> row-blocked Pallas bitonic sort
                                         (block_rows tiles per grid program)
  step 3  s equidistant local samples -> fused epilogue output of step 2
  step 4  sort all samples            -> recursive call on the sample array
  step 5  s equidistant global samples-> strided slice of sorted samples
  step 6  sample indexing             -> fused Pallas splitter-partition
                                         kernel (ranks + bucket counts)
  step 7  column-major prefix sum     -> cumsums over (rows, m, s) counts
  step 8  data relocation             -> gather: source index per bucket
                                         slot, then one `take`
  step 9  sublist sort                -> recursion on bucket rows, then a
                                         gather-based compaction back to
                                         dense rows

PLANNER / EXECUTOR SPLIT (DESIGN.md §7): deterministic regular
sampling makes the whole multi-level schedule — recursion levels,
per-level rows x tile geometry, s_round, capacities, pad budgets,
kernel block sizes — a pure function of (shape, dtype, config).
``core/plan.build_plan`` computes it ONCE as a frozen ``SortPlan``
tree; the ``_run_node`` executor below merely walks it, and the jit'd
canonical entry takes the plan as its static argument, so equal plans
(the memoized builder object, or a plan reloaded from the
``core/autotune`` persistent cache) share one compiled executable:
same-signature calls trace exactly once and a plan-cache hit retraces
zero times (``trace_count`` exposes the counter; tests assert it).
``SortConfig.plan`` selects how plans are obtained ("default" /
"autotune" / a plan-file path); ``sort_planned`` executes an explicit
plan.

TPU adaptation (see DESIGN.md §2): buckets live in a DENSE (rows*s, B)
array with static capacity B = L/s_round + L/s — the deterministic
regular-sampling bound makes this capacity *guaranteed*, which is what
lets the whole sort be expressed with static shapes (a hard requirement
under XLA).  Randomized sample sort admits no such static capacity.

The guarantee holds PER ROW, so the same machinery sorts many
independent arrays in one launch (DESIGN.md §5): the batched entry
points put B independent sorts on the rows of one (B, L) array and run
the whole batch through a single `_sort_rows` recursion — one kernel
launch per pipeline step for the entire batch, no vmap over the 1-D
entry point, no per-row retracing.

DTYPE GENERICITY (DESIGN.md §6): the engine is dtype-agnostic — it
sorts tuples of canonical uint32 KEY WORDS (most significant first)
lexicographically, with the int32 payload as the final tiebreak.  A
``core/key_codec`` codec maps each user dtype to that domain: one word
for <= 32-bit dtypes (int32/uint32/float32, widened bool/8/16-bit),
hi/lo pairs for int64/uint64/float64, and an order-reversing complement
for ``SortConfig.descending``.  Every public entry point below supports
every codec dtype; 64-bit dtypes need x64 mode enabled.

Relocation/compaction are SCATTER-FREE on the default path (DESIGN.md
§4): both passes compute, for every destination slot, the source index
it must read (via a binary search over the chunk-offset tables) and
gather with `take`.  XLA serializes large 1-D scatters; gathers it
vectorizes.  ``cfg.relocation="scatter"`` keeps the legacy
destination-scatter formulation as a reference path.

Correctness invariants (tested, incl. hypothesis properties):
  * elements are (key, payload) pairs, payload = original index within
    the row => all pairs are unique PER ROW (rows never compare against
    each other) => the capacity bound holds for ANY input (duplicates
    included) and the sort is STABLE;
  * pad elements introduced anywhere in the recursion draw payloads
    from one monotone per-row range (threaded ``pad_base``): pad
    payloads are unique within their row, exceed every real payload in
    the row, sort after every real element, and nothing is ever
    silently dropped (asserted in tests).  ``pad_base`` advances by
    per-row amounts, so the int32 payload budget is independent of the
    batch size.

Usage (see docs/api.md for the full reference)::

    from repro.core import bucket_sort
    from repro.core.sort_config import SortConfig

    y = bucket_sort.sort(x)                    # 1-D, ascending, stable
    perm = bucket_sort.argsort(x)              # == np.argsort(x, kind="stable")
    sk, sv = bucket_sort.sort_kv(x, payload)   # payload rides along
    y = bucket_sort.sort(x, SortConfig(descending=True))   # stable desc

    # Batched: B independent sorts in ONE launch (B, L) -> (B, L).
    ys = bucket_sort.sort_batched(xs)
    perms = bucket_sort.argsort_batched(xs)
    sk, sv = bucket_sort.sort_kv_batched(xs, payloads)

    # Segmented (ragged): sort within [off[i], off[i+1]) independently.
    # segment_offsets must be host-known ints (static shapes under XLA).
    y = bucket_sort.segment_sort(x, [0, 3, 3, 10, len(x)])
    perm = bucket_sort.segment_argsort(x, offsets)   # global indices

    # Bound introspection (paper's capacity guarantee):
    y, perm, stats = bucket_sort.sort_with_stats(x)          # 1-D
    ys, perms, stats = bucket_sort.sort_batched_with_stats(xs)
    # stats: one dict per bucket round; [] when the input fits
    # cfg.direct_max (single-tile path, no bucket round).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import guard
from repro.core import plan as plan_mod
from repro.core.key_codec import codec_for
from repro.core.plan import LevelPlan, SortPlan, build_plan
from repro.core.sort_config import DEFAULT_CONFIG, SortConfig, round_up
from repro.kernels import ops

_MAXU = jnp.uint32(0xFFFFFFFF)
_INT_MAX = 2**31 - 1

# Python-side retrace counter: incremented once per TRACE of the jit'd
# canonical entry (not per call).  ``tests/test_plan.py`` asserts the
# compile-count discipline with it: same (shape, dtype, cfg) => one
# trace; a plan-cache hit => zero new traces.
_TRACE_COUNT = 0


def trace_count() -> int:
    """Number of times the canonical packed entry has been TRACED in
    this process (a retrace/compile-discipline counter for tests)."""
    return _TRACE_COUNT


def _pad_cols(kw, vals, new_len, pad_base):
    """Pad the last axis to new_len with (all-ones words, pad_base + j).

    Args:
        kw: tuple of (r, L) uint32 key-word arrays (msw first).
        vals: (r, L) int32 payloads.
    Returns:
        (padded kw, padded vals, advanced pad_base).

    Pad payloads are unique PER ROW (rows never compare against each
    other) and >= pad_base > every real payload in the row, so pads
    sort after all real elements and the pad budget is independent of
    the row count.
    """
    r, length = kw[0].shape
    extra = new_len - length
    if extra == 0:
        return kw, vals, pad_base
    pk = jnp.full((r, extra), _MAXU, jnp.uint32)
    pv = jnp.int32(pad_base) + jax.lax.broadcasted_iota(
        jnp.int32, (r, extra), 1
    )
    kw = tuple(jnp.concatenate([w, pk], axis=1) for w in kw)
    vals = jnp.concatenate([vals, pv], axis=1)
    return kw, vals, pad_base + extra


def _direct_sort(kw, vals, node: LevelPlan, impl, interpret, pad_base):
    """Single-tile local sort of each row (rows, L), L <= direct_max;
    all geometry (pow2-padded width, kernel block size) AND the
    local-sort strategy are plan-carried (DESIGN.md §8)."""
    length = kw[0].shape[1]
    kw, vals, pad_base = _pad_cols(kw, vals, node.lp, pad_base)
    sk, sv = ops.sort_tiles(
        kw, vals, impl=impl, interpret=interpret,
        block_rows=node.block_rows, strategy=node.strategy,
        radix_bits=node.radix_bits, merge_run=node.merge_run,
    )
    return tuple(w[:, :length] for w in sk), sv[:, :length], pad_base


def _chunk_search(offsets, positions):
    """For each row: index of the chunk containing each position.

    Args:
        offsets: (Q, C) non-decreasing exclusive chunk starts
            (offsets[:, 0] == 0).
        positions: (Q, P) query positions.
    Returns:
        (Q, P) int32 j with offsets[q, j] <= positions[q, p] <
        offsets[q, j+1] — i.e. the LAST chunk starting at or before the
        position, which skips empty chunks (ties in ``offsets``)
        correctly.  Pure binary search: lowers to gathers, never a
        scatter.
    """
    find = jax.vmap(lambda o, p: jnp.searchsorted(o, p, side="right"))
    return find(offsets, positions).astype(jnp.int32) - 1


def _relocate_gather(tkw, tv, starts, tile_off, totals, r, m, s_round, t, cap,
                     pad_base):
    """Step 8, scatter-free (DESIGN.md §4): for every slot of the dense
    (r*s_round, cap) bucket array compute the SOURCE element it receives,
    then gather (one `take` per key word + one for the payload).

    Bucket row q = r'*s_round + j receives, tile by tile, the elements
    of tile i = 0..m-1 of data row r' that fall in key range j; tile i's
    chunk lands at offset tile_off[r', i, j] and is read from the sorted
    tile starting at starts[r'*m + i, j].  Slot p of bucket row q
    therefore reads from the tile whose chunk covers p (binary search
    over the m chunk offsets), at chunk-relative position p - chunk
    offset.  Slots past the true fill (p >= totals) become fresh pads,
    unique within their bucket row.
    """
    # Per-bucket-row views: (r*s_round, m) chunk offsets / tile starts.
    offs = tile_off.transpose(0, 2, 1).reshape(r * s_round, m)
    st = starts.reshape(r, m, s_round).transpose(0, 2, 1).reshape(r * s_round, m)
    p = jax.lax.broadcasted_iota(jnp.int32, (r * s_round, cap), 1)
    src_tile = _chunk_search(offs, p)  # (r*s_round, cap) tile index
    src_start = jnp.take_along_axis(st, src_tile, axis=1)
    src_off = jnp.take_along_axis(offs, src_tile, axis=1)
    row_base = (
        jax.lax.broadcasted_iota(jnp.int32, (r * s_round, cap), 0) // s_round
    ) * m
    src = (row_base + src_tile) * t + src_start + (p - src_off)
    valid = p < totals.reshape(r * s_round, 1)
    src = jnp.where(valid, src, 0)
    srcf = src.reshape(-1)
    bkw = tuple(
        jnp.where(
            valid, jnp.take(w.reshape(-1), srcf).reshape(src.shape), _MAXU
        )
        for w in tkw
    )
    gv = jnp.take(tv.reshape(-1), srcf).reshape(src.shape)
    pad_v = jnp.int32(pad_base) + p
    bv = jnp.where(valid, gv, pad_v)
    return bkw, bv


def _relocate_scatter(tkw, tv, ranks, starts, tile_off, r, m, s_round, t, cap,
                      pad_base):
    """Step 8, legacy destination-scatter reference path: compute each
    ELEMENT's destination slot and scatter.  XLA serializes the
    full-size 1-D scatters; kept only for cfg.relocation="scatter"."""
    pos = jax.lax.broadcasted_iota(jnp.int32, (r * m, t), 1)
    ind = jnp.zeros((r * m, t + 1), jnp.int32)
    ind = ind.at[
        jax.lax.broadcasted_iota(jnp.int32, ranks.shape, 0), ranks
    ].add(1)
    bucket_id = jnp.cumsum(ind, axis=1, dtype=jnp.int32)[:, :t]  # (r*m, T)
    p_rel = pos - jnp.take_along_axis(starts, bucket_id, axis=1)
    within = (
        jnp.take_along_axis(tile_off.reshape(r * m, s_round), bucket_id, axis=1)
        + p_rel
    )
    row_id = jax.lax.broadcasted_iota(jnp.int32, (r * m, t), 0) // m
    dest = (row_id * s_round + bucket_id) * cap + within
    # The capacity bound guarantees within < cap; tests assert no drops.
    dest = jnp.where(within < cap, dest, r * s_round * cap)
    destf = dest.reshape(-1)

    # Unwritten slots hold the same per-row pads as the gather path.
    bkw = tuple(
        jnp.full((r * s_round * cap,), _MAXU, jnp.uint32)
        .at[destf].set(w.reshape(-1), mode="drop")
        .reshape(r * s_round, cap)
        for w in tkw
    )
    bv = (
        jnp.int32(pad_base)
        + jax.lax.broadcasted_iota(jnp.int32, (r * s_round, cap), 1)
    ).reshape(-1)
    bv = bv.at[destf].set(tv.reshape(-1), mode="drop")
    return bkw, bv.reshape(r * s_round, cap)


def _compact_gather(ckw, cv, totals, r, s_round, cap, lp):
    """Step 9 compaction, scatter-free: dense column c of data row r'
    reads from bucket j covering c (binary search over the s_round
    bucket offsets) at position c - bucket_off.  Bucket fills sum to lp
    per row, so every dense slot has exactly one source — no pads."""
    bucket_off = jnp.cumsum(totals, axis=1, dtype=jnp.int32) - totals  # (r, s_round)
    c = jax.lax.broadcasted_iota(jnp.int32, (r, lp), 1)
    srcj = _chunk_search(bucket_off, c)  # (r, lp) bucket index
    within = c - jnp.take_along_axis(bucket_off, srcj, axis=1)
    row = jax.lax.broadcasted_iota(jnp.int32, (r, lp), 0)
    src = (row * s_round + srcj) * cap + within
    srcf = src.reshape(-1)
    okw = tuple(jnp.take(w.reshape(-1), srcf).reshape(r, lp) for w in ckw)
    ov = jnp.take(cv.reshape(-1), srcf).reshape(r, lp)
    return okw, ov


def _compact_scatter(ckw, cv, totals, r, s_round, cap, lp):
    """Step 9 compaction, legacy scatter reference path."""
    bucket_off = jnp.cumsum(totals, axis=1, dtype=jnp.int32) - totals  # (r, s_round)
    p = jax.lax.broadcasted_iota(jnp.int32, (r * s_round, cap), 1)
    valid = p < totals.reshape(r * s_round, 1)
    drow = jax.lax.broadcasted_iota(jnp.int32, (r * s_round, cap), 0) // s_round
    dcol = bucket_off.reshape(r * s_round, 1) + p
    dflat = jnp.where(valid, drow * lp + dcol, r * lp).reshape(-1)
    okw = tuple(
        jnp.full((r * lp,), _MAXU, jnp.uint32)
        .at[dflat].set(w.reshape(-1), mode="drop")
        .reshape(r, lp)
        for w in ckw
    )
    ov = jnp.full((r * lp,), jnp.int32(_INT_MAX))
    ov = ov.at[dflat].set(cv.reshape(-1), mode="drop")
    return okw, ov.reshape(r, lp)


def _run_node(kw, vals, node: LevelPlan, impl: str, interpret: bool,
              pad_base: int, stats: list | None):
    """EXECUTOR: sort each row of (rows, L) canonical key words / int32
    payloads by walking one node of the plan tree.

    Every static quantity — padded lengths, tile counts, ``s_round``,
    capacities, kernel block sizes, fusion/relocation choices — is read
    off the :class:`repro.core.plan.LevelPlan`; the executor derives
    NOTHING (the planner/executor split, DESIGN.md §7).

    Args:
        kw: tuple of (rows, L) uint32 key-word arrays (msw first).
        vals: (rows, L) int32 payloads, unique per row.
        node: the plan node matching (rows, L) exactly.
    Returns:
        (sorted kw, sorted vals, pad_base) with dense sorted rows of the
        input shape.  Static walk: every shape is trace-time known;
        ``pad_base`` is a trace-time python int tracking the per-row pad
        payload high-water mark (batch-size independent, DESIGN.md §5).
    """
    r, length = kw[0].shape
    assert (r, length) == (node.rows, node.length), (
        f"plan/data mismatch: data {(r, length)} vs plan node "
        f"{(node.rows, node.length)}"
    )
    if node.kind == "direct":
        return _direct_sort(kw, vals, node, impl, interpret, pad_base)

    t, sper, lp, m = node.tile, node.s, node.lp, node.m
    s_round, cap = node.s_round, node.cap
    kw, vals, pad_base = _pad_cols(kw, vals, lp, pad_base)

    # Steps 1-3: row-blocked local tile sort, sample extraction fused in.
    tkw = tuple(w.reshape(r * m, t) for w in kw)
    tv = vals.reshape(r * m, t)
    if node.fuse_sampling:
        tkw, tv, samp_kw, samp_v = ops.sort_tiles_sample(
            tkw, tv, num_samples=sper, impl=impl,
            interpret=interpret, block_rows=node.block_rows,
            strategy=node.strategy, radix_bits=node.radix_bits,
            merge_run=node.merge_run,
        )
        samples_kw = tuple(w.reshape(r, m * sper) for w in samp_kw)
        samples_v = samp_v.reshape(r, m * sper)
    else:
        tkw, tv = ops.sort_tiles(
            tkw, tv, impl=impl, interpret=interpret,
            block_rows=node.block_rows, strategy=node.strategy,
            radix_bits=node.radix_bits, merge_run=node.merge_run,
        )
        samp_idx = (jnp.arange(1, sper + 1, dtype=jnp.int32) * (t // sper)) - 1
        samples_kw = tuple(w[:, samp_idx].reshape(r, m * sper) for w in tkw)
        samples_v = tv[:, samp_idx].reshape(r, m * sper)

    # Step 4: sort all samples (recursive; sample array is L*s/T << L).
    sskw, ssv, pad_base = _run_node(
        samples_kw, samples_v, node.sample_plan, impl, interpret, pad_base,
        None,
    )

    # Step 5: s_round - 1 equidistant global splitters.
    total_samples = m * sper
    sp_idx = (jnp.arange(1, s_round, dtype=jnp.int32) * total_samples) // s_round
    spkw = tuple(w[:, sp_idx] for w in sskw)  # (r, s_round-1) each
    spv = ssv[:, sp_idx]

    # Steps 6-7: splitter ranks + per-tile bucket counts (fused epilogue),
    # then the column-major prefix sums over (rows, m, s_round).
    spkw_t = tuple(jnp.repeat(w, m, axis=0) for w in spkw)  # (r*m, s_round-1)
    spv_t = jnp.repeat(spv, m, axis=0)
    if node.fuse_ranking:
        ranks, counts2 = ops.splitter_partition(
            tkw, tv, spkw_t, spv_t, impl=impl, interpret=interpret,
            block_rows=node.part_block_rows,
        )  # ranks (r*m, s_round-1); counts2 (r*m, s_round)
    else:
        ranks = ops.splitter_ranks(
            tkw, tv, spkw_t, spv_t, impl=impl, interpret=interpret
        )  # (r*m, s_round-1), values in [0, T]
        ends = jnp.concatenate(
            [ranks, jnp.full((r * m, 1), t, jnp.int32)], axis=1
        )
        counts2 = ends - jnp.concatenate(
            [jnp.zeros((r * m, 1), jnp.int32), ranks], axis=1
        )
    starts = jnp.concatenate(
        [jnp.zeros((r * m, 1), jnp.int32), ranks], axis=1
    )  # (r*m, s_round): start of bucket j within tile i
    counts = counts2.reshape(r, m, s_round)
    # offset of tile i's chunk within bucket j of its row (exclusive cumsum):
    tile_off = jnp.cumsum(counts, axis=1, dtype=jnp.int32) - counts  # (r, m, s_round)
    totals = counts.sum(axis=1, dtype=jnp.int32)  # (r, s_round) true bucket fills

    # Step 8: relocation into the dense (r*s_round, cap) bucket array.
    if node.relocation == "gather":
        bkw, bv = _relocate_gather(
            tkw, tv, starts, tile_off, totals, r, m, s_round, t, cap, pad_base
        )
    else:
        bkw, bv = _relocate_scatter(
            tkw, tv, ranks, starts, tile_off, r, m, s_round, t, cap, pad_base
        )
    pad_base += cap

    if stats is not None:
        stats.append(
            dict(
                level_len=lp,
                rows=r,
                s_round=s_round,
                capacity=cap,
                totals=totals,
                # every bucket's elements sit at 0..fill-1 of their row
                max_within=jnp.max(totals) - 1,
            )
        )

    # Step 9: sort every bucket row (recursion), then compact to dense rows.
    ckw, cv, pad_base = _run_node(
        bkw, bv, node.bucket_plan, impl, interpret, pad_base, stats
    )

    # Compaction: first totals[q, j] entries of bucket row (q, j) are exactly
    # the elements this level relocated there (fresh pads sort after them).
    if node.relocation == "gather":
        okw, ov = _compact_gather(ckw, cv, totals, r, s_round, cap, lp)
    else:
        okw, ov = _compact_scatter(ckw, cv, totals, r, s_round, cap, lp)
    return tuple(w[:, :length] for w in okw), ov[:, :length], pad_base


def _sort_rows(kw, vals, cfg: SortConfig, pad_base: int, stats: list | None):
    """Plan-building shim over the executor for callers holding canonical
    word tuples mid-trace (``distributed_sort`` local sorts): builds the
    words-plan for the (rows, L) shape through the same builder and
    walks it."""
    r, length = kw[0].shape
    p = plan_mod.build_words_plan(length, len(kw), cfg, rows=r)
    return _run_node(kw, vals, p.root, p.impl, p.interpret, pad_base, stats)


@functools.partial(
    jax.jit, static_argnames=("plan", "pad_base0", "with_stats")
)
def _sort_canonical_packed(keys_words, vals, plan: SortPlan, pad_base0: int,
                           with_stats: bool = False):
    """Row-native canonical entry: (B, L) key words + int32 payloads.

    ``plan`` is a STATIC argument: equal plans (e.g. the same memoized
    object, or a plan reloaded from the persistent cache) hash to the
    same jit cache entry, so repeated same-signature calls trace and
    compile exactly once (asserted in tests/test_plan.py).

    Args:
        keys_words: tuple of (B, L) uint32 key-word arrays (msw first),
            with B == plan.rows_padded and L == plan.length.
        vals: (B, L) int32 payloads.
        plan: the static schedule to walk (see ``core/plan.py``).
        pad_base0: must exceed every payload already present in ``vals``
            (per row) so recursion-introduced pads sort after real
            elements.
    Returns:
        (sorted words, sorted vals[, stats]).
    """
    global _TRACE_COUNT
    _TRACE_COUNT += 1  # python side effect: runs once per TRACE
    stats: list | None = [] if with_stats else None
    kw = tuple(keys_words)
    skw, sv, pad_base = _run_node(
        kw, vals, plan.root, plan.impl, plan.interpret, pad_base0, stats
    )
    assert pad_base < _INT_MAX, (
        f"pad payload budget exhausted ({pad_base}); reduce L or raise s/tile"
    )
    if with_stats:
        return skw, sv, stats
    return skw, sv


def resolve_plan(length: int, dtype, cfg: SortConfig, *, rows: int = 1,
                 pad_rows: bool = False) -> SortPlan:
    """Obtain the plan for a sort signature per ``cfg.plan``:

      * ``"default"``  — :func:`repro.core.plan.build_plan` (memoized);
      * ``"autotune"`` — measured-best plan via ``core/autotune``
        (persistent on-disk cache; tunes on the first miss);
      * a path — a plan file saved by ``autotune.save_plan``; its
        signature must match (ValueError otherwise).
    """
    if cfg.plan == "default":
        return build_plan(length, dtype, cfg, rows=rows, pad_rows=pad_rows)
    from repro.core import autotune  # deferred: autotune imports us

    if cfg.plan == "autotune":
        return autotune.plan_for(
            length, dtype, cfg, rows=rows, pad_rows=pad_rows
        )
    return autotune.load_plan(
        cfg.plan, length=length, dtype=dtype, cfg=cfg, rows=rows,
        pad_rows=pad_rows,
    )


@jax.jit
def _reference_sort_packed(kw, vals):
    """Last chain link of the degradation ladder (DESIGN.md §11): one
    ``jax.lax.sort`` over (key words..., payload) — no pallas, no plan
    machinery, the same formulation as ``baselines.xla_sort``.  Correct
    for any canonical input; slower (no tiling, no fused steps)."""
    out = jax.lax.sort(tuple(kw) + (vals,), dimension=1,
                       num_keys=len(kw) + 1)
    return tuple(out[:-1]), out[-1]


def _fallback_plan(plan: SortPlan) -> SortPlan | None:
    """Stage-2 degradation target: a default-config xla stand-in plan
    for the same (rows, length) canonical-words signature.  ``None``
    when it would equal the failing plan (nothing left to vary before
    the reference path)."""
    try:
        alt = plan_mod.build_words_plan(
            plan.length, plan.num_words,
            SortConfig(impl="xla", interpret=False),
            rows=plan.rows_padded,
        )
    except Exception:
        return None
    return None if alt == plan else alt


def _execute_packed(kw, vals, plan: SortPlan, pad_base0: int, *,
                    check: str = "off", degrade: bool = True,
                    with_stats: bool = False):
    """Guarded, degrading funnel every packed entry point runs through.

    Executes ``plan`` via the jit'd canonical entry, then applies the
    ``check`` invariants (``core/guard.py``): ``'bounds'`` verifies the
    paper's capacity bound on the measured bucket fills of each round,
    ``'full'`` adds permutation checksums + sortedness on the output.

    With ``degrade=True`` any failure — kernel launch error, injected
    fault (``core/faults.py``), or a check violation — walks the
    degradation chain (DESIGN.md §11):

      1. the resolved plan as given;
      2. a default-config ``impl='xla'`` stand-in plan (fresh trace —
         failed traces are never cached, so a transient launch fault
         does not poison the chain);
      3. the ``jax.lax.sort`` reference (no plan machinery at all).

    Each step re-runs the checks; events land in
    ``guard.degradation_log()``.  ``degrade=False`` (the explicit-plan
    API) propagates the structured error instead.  Returns
    (kw, vals[, stats]); a run degraded to the reference path reports
    ``stats == []`` (the reference has no bucket rounds).
    """
    guard.validate_check(check)
    want_stats = with_stats or check != "off"

    def run(p: SortPlan):
        out = _sort_canonical_packed(kw, vals, p, pad_base0, want_stats)
        skw, sv, stats = out if want_stats else (out[0], out[1], [])
        if check != "off":
            guard.check_bounds(p, stats)
        if check == "full":
            guard.check_full(p, kw, vals, skw, sv)
        return skw, sv, stats

    try:
        skw, sv, stats = run(plan)
    except Exception as e1:
        if not degrade:
            raise
        alt = _fallback_plan(plan)
        skw = None
        if alt is not None:
            guard.record_degradation(
                guard.plan_site(plan), "fallback", f"impl={plan.impl} plan",
                "default xla stand-in plan", e1)
            try:
                skw, sv, stats = run(alt)
            except Exception as e2:
                e1 = e2
        if skw is None:
            guard.record_degradation(
                guard.plan_site(plan), "fallback",
                "plan execution", "jax.lax.sort reference", e1)
            skw, sv = _reference_sort_packed(kw, vals)
            stats = []
            if check == "full":
                guard.check_full(plan, kw, vals, skw, sv)
    if with_stats:
        return skw, sv, stats
    return skw, sv


def _sort_canonical_rows(kw, plan: SortPlan, with_stats: bool = False,
                         check: str = "off"):
    """(B, L) canonical sort with payload = original index within the row."""
    b, n = kw[0].shape
    vals = jnp.broadcast_to(jnp.arange(n, dtype=jnp.int32)[None, :], (b, n))
    return _execute_packed(kw, vals, plan, n, check=check,
                           with_stats=with_stats)


def _sort_canonical(kw, plan: SortPlan, with_stats: bool = False,
                    check: str = "off"):
    """1-D canonical entry (single logical row of the batched path)."""
    out = _sort_canonical_rows(tuple(w[None, :] for w in kw), plan,
                               with_stats, check)
    skw = tuple(w[0] for w in out[0])
    if with_stats:
        return skw, out[1][0], out[2]
    return skw, out[1][0]


def _pad_rows(kw, vals, plan: SortPlan):
    """Batch-aware block_rows auto-pick (DESIGN.md §5): pad the row
    count to the plan's ``rows_padded`` with all-pad rows so
    ``auto_block_rows`` always finds a power-of-two divisor >= row_pad
    and the row-blocked kernels get dense sublane blocks (the planner
    applies the rule only on the pallas path).  Returns (kw, vals);
    callers slice [:plan.rows] out.
    """
    b, length = kw[0].shape
    extra = plan.rows_padded - b
    if extra <= 0:
        return kw, vals
    pk = jnp.full((extra, length), _MAXU, jnp.uint32)
    pv = jnp.broadcast_to(
        jnp.arange(length, dtype=jnp.int32)[None, :], (extra, length)
    )
    return (
        tuple(jnp.concatenate([w, pk], axis=0) for w in kw),
        jnp.concatenate([vals, pv], axis=0),
    )


# ----------------------------------------------------------------------
# Public 1-D API
# ----------------------------------------------------------------------


def sort(keys: jax.Array, cfg: SortConfig = DEFAULT_CONFIG) -> jax.Array:
    """Deterministic sample sort of a 1-D array (stable, total order).

    Args:
        keys: 1-D array of any codec dtype — int8/16/32/64, uint8/16/32/64,
            float16/bfloat16/float32/float64, bool (64-bit dtypes need
            x64 mode).  Floats follow the IEEE total order (NaN last
            ascending).
        cfg: pipeline knobs; ``cfg.descending`` flips the order
            (stable, codec-level — see SortConfig).
    Returns:
        Sorted array, same shape/dtype.

    Example:
        >>> import jax.numpy as jnp
        >>> from repro.core import bucket_sort
        >>> bucket_sort.sort(jnp.asarray([3, 1, 2]))
        Array([1, 2, 3], dtype=int32)
    """
    if keys.shape[0] <= 1:
        return keys
    codec = codec_for(keys.dtype, cfg.descending)
    plan = resolve_plan(keys.shape[0], keys.dtype, cfg)
    su, _ = _sort_canonical(codec.encode(keys), plan, check=cfg.check)
    return codec.decode(su)


def argsort(keys: jax.Array, cfg: SortConfig = DEFAULT_CONFIG) -> jax.Array:
    """Stable argsort via deterministic sample sort.

    Args:
        keys: 1-D array of any codec dtype (see :func:`sort`).
        cfg: pipeline knobs; ``cfg.descending`` gives the stable
            descending permutation (ties keep input order), matching
            ``jnp.argsort(x, descending=True, stable=True)``.
    Returns:
        int32 permutation, == ``np.argsort(keys, kind="stable")`` when
        ascending.

    Example:
        >>> import jax.numpy as jnp
        >>> from repro.core import bucket_sort
        >>> bucket_sort.argsort(jnp.asarray([30.0, 10.0, 20.0]))
        Array([1, 2, 0], dtype=int32)
    """
    if keys.shape[0] <= 1:
        return jnp.arange(keys.shape[0], dtype=jnp.int32)
    codec = codec_for(keys.dtype, cfg.descending)
    plan = resolve_plan(keys.shape[0], keys.dtype, cfg)
    _, perm = _sort_canonical(codec.encode(keys), plan, check=cfg.check)
    return perm


def sort_kv(keys: jax.Array, values: jax.Array, cfg: SortConfig = DEFAULT_CONFIG):
    """Stable (keys, values) sort by keys.

    Args:
        keys: 1-D array of any codec dtype (see :func:`sort`), length n.
        values: any array with leading dim n; permuted along axis 0.
        cfg: pipeline knobs (``descending`` supported).
    Returns:
        (sorted_keys, values[perm]).
    """
    assert keys.ndim == 1 and values.shape[0] == keys.shape[0]
    n = keys.shape[0]
    if n <= 1:
        return keys, values
    codec = codec_for(keys.dtype, cfg.descending)
    plan = resolve_plan(n, keys.dtype, cfg)
    su, perm = _sort_canonical(codec.encode(keys), plan, check=cfg.check)
    return codec.decode(su), jnp.take(values, perm, axis=0)


def sort_with_stats(keys: jax.Array, cfg: SortConfig = DEFAULT_CONFIG):
    """Sort + per-round stats (capacities, bucket fills) for bound tests.

    Args:
        keys: 1-D array of any codec dtype.
    Returns:
        (sorted, perm, stats).  ``stats`` has one dict per bucket round
        (keys: level_len, rows, s_round, capacity, totals, max_within).
        Inputs that fit ``cfg.direct_max`` take the single-tile bitonic
        path and run ZERO bucket rounds: stats is a well-defined EMPTY
        list — callers must check before indexing.
    """
    n = keys.shape[0]
    if n <= 1:
        return keys, jnp.arange(n, dtype=jnp.int32), []
    codec = codec_for(keys.dtype, cfg.descending)
    plan = resolve_plan(n, keys.dtype, cfg)
    su, perm, stats = _sort_canonical(
        codec.encode(keys), plan, with_stats=True, check=cfg.check
    )
    return codec.decode(su), perm, stats


def sort_planned(keys: jax.Array, plan: SortPlan,
                 check: str = "off") -> jax.Array:
    """Sort with an EXPLICIT :class:`~repro.core.plan.SortPlan`.

    The autotuner's measurement entry and the zero-retrace serving
    path: the plan is the jit static argument, so every call carrying
    an equal plan (the memoized builder object, or one reloaded from
    the persistent cache) reuses one compiled executable.

    Unlike the config-driven entries, an explicit plan is executed
    WITHOUT degradation (``degrade=False``): the caller asked for this
    exact schedule, so a failure — including a ``check`` invariant
    violation (:class:`repro.core.guard.SortRuntimeError`) — raises
    rather than silently substituting a different plan.

    Args:
        keys: 1-D (plan.rows == 1) or 2-D (B, L) array whose
            shape/dtype match the plan signature.
        plan: a plan from :func:`repro.core.plan.build_plan`,
            ``autotune.plan_for``, or ``autotune.load_plan``.
        check: runtime invariant mode, ``'off' | 'bounds' | 'full'``
            (see DESIGN.md §11).
    Returns:
        Sorted array of keys' shape/dtype (each row independently for
        2-D), descending iff the plan was built from a descending cfg.
    Raises:
        ValueError: when keys' shape or dtype do not match the plan.
        repro.core.guard.SortRuntimeError: when ``check`` detects an
            invariant violation for this plan.
    """
    shape = (
        (1, keys.shape[0]) if keys.ndim == 1
        else (keys.shape[0], keys.shape[1])
    )
    if shape != (plan.rows, plan.length) or (
        jnp.dtype(keys.dtype).name != plan.dtype_name
    ):
        raise ValueError(
            f"keys {keys.shape}/{jnp.dtype(keys.dtype).name} do not match "
            f"plan signature rows={plan.rows} length={plan.length} "
            f"dtype={plan.dtype_name}"
        )
    if plan.length <= 1:
        return keys
    codec = codec_for(keys.dtype, plan.descending)
    if keys.ndim == 1:
        kw1 = tuple(w[None, :] for w in codec.encode(keys))
        vals = jnp.broadcast_to(
            jnp.arange(plan.length, dtype=jnp.int32)[None, :],
            (1, plan.length),
        )
        sk, _ = _execute_packed(kw1, vals, plan, plan.length,
                                check=check, degrade=False)
        return codec.decode(tuple(w[0] for w in sk))
    vals = jnp.broadcast_to(
        jnp.arange(plan.length, dtype=jnp.int32)[None, :], keys.shape
    )
    kw, vals = _pad_rows(codec.encode(keys), vals, plan)
    sk, _ = _execute_packed(kw, vals, plan, plan.length,
                            check=check, degrade=False)
    return codec.decode(tuple(w[:plan.rows] for w in sk))


# ----------------------------------------------------------------------
# Batched API: B independent sorts on the rows of (B, L), one launch
# ----------------------------------------------------------------------


def _batched_entry(keys, cfg: SortConfig):
    """Shared batched preamble: plan resolution, canonical key words,
    per-row index payloads, row_pad alignment.  Returns
    (codec, plan, kw, vals, b) — slice results [:b]."""
    b, length = keys.shape
    codec = codec_for(keys.dtype, cfg.descending)
    plan = resolve_plan(length, keys.dtype, cfg, rows=b, pad_rows=True)
    kw, vals = _pad_rows(
        codec.encode(keys),
        jnp.broadcast_to(jnp.arange(length, dtype=jnp.int32)[None, :],
                         (b, length)),
        plan,
    )
    return codec, plan, kw, vals, b


def sort_batched(keys: jax.Array, cfg: SortConfig = DEFAULT_CONFIG) -> jax.Array:
    """Sort each row of a (B, L) array independently (stable).

    Equivalent to B independent 1-D ``sort`` calls, but the whole batch
    enters the row-native pipeline with rows=B: one kernel launch per
    pipeline step for the entire batch (DESIGN.md §5).

    Args:
        keys: (B, L) array of any codec dtype (see :func:`sort`).
        cfg: pipeline knobs (``descending`` supported).
    Returns:
        (B, L) array, every row sorted.
    """
    assert keys.ndim == 2, keys.shape
    b, length = keys.shape
    if b == 0 or length <= 1:
        return keys
    codec, plan, kw, vals, b = _batched_entry(keys, cfg)
    sk, _ = _execute_packed(kw, vals, plan, length, check=cfg.check)
    return codec.decode(tuple(w[:b] for w in sk))


def argsort_batched(keys: jax.Array, cfg: SortConfig = DEFAULT_CONFIG):
    """Per-row stable argsort of (B, L): row i of the result is
    ``np.argsort(keys[i], kind="stable")`` (descending via cfg).

    Args:
        keys: (B, L) array of any codec dtype.
    Returns:
        (B, L) int32 permutations.
    """
    assert keys.ndim == 2, keys.shape
    b, length = keys.shape
    if b == 0 or length <= 1:
        return jnp.broadcast_to(
            jnp.arange(length, dtype=jnp.int32)[None, :], (b, length)
        )
    _, plan, kw, vals, b = _batched_entry(keys, cfg)
    _, perm = _execute_packed(kw, vals, plan, length, check=cfg.check)
    return perm[:b]


def sort_kv_batched(keys: jax.Array, values: jax.Array,
                    cfg: SortConfig = DEFAULT_CONFIG):
    """Per-row stable (keys, values) sort of (B, L) keys by keys.

    Args:
        keys: (B, L) array of any codec dtype.
        values: (B, L, ...) — any trailing shape; permuted along axis 1
            with each row's permutation.
    Returns:
        (sorted_keys (B, L), permuted values).
    """
    assert keys.ndim == 2 and values.shape[:2] == keys.shape, (
        keys.shape, values.shape
    )
    b, length = keys.shape
    if b == 0 or length <= 1:
        return keys, values
    codec, plan, kw, vals, b = _batched_entry(keys, cfg)
    sk, perm = _execute_packed(kw, vals, plan, length, check=cfg.check)
    sk, perm = tuple(w[:b] for w in sk), perm[:b]
    idx = perm.reshape(perm.shape + (1,) * (values.ndim - 2))
    sv = jnp.take_along_axis(values, idx, axis=1)
    return codec.decode(sk), sv


def sort_batched_with_stats(keys: jax.Array, cfg: SortConfig = DEFAULT_CONFIG):
    """Batched sort + per-round stats over the WHOLE batch.

    Each stats entry's ``totals`` covers every row of that recursion
    level (top level: the B batch rows, plus all-pad alignment rows on
    the pallas path — pads obey the same bound).  Like
    ``sort_with_stats``, stats is [] when L fits ``cfg.direct_max``.
    """
    assert keys.ndim == 2, keys.shape
    b, length = keys.shape
    if b == 0 or length <= 1:
        perm = jnp.broadcast_to(
            jnp.arange(length, dtype=jnp.int32)[None, :], (b, length)
        )
        return keys, perm, []
    codec, plan, kw, vals, b = _batched_entry(keys, cfg)
    sk, perm, stats = _execute_packed(
        kw, vals, plan, length, with_stats=True, check=cfg.check
    )
    return codec.decode(tuple(w[:b] for w in sk)), perm[:b], stats


# ----------------------------------------------------------------------
# Segmented API: ragged independent sorts, packed into padded rows
# ----------------------------------------------------------------------


def _segment_layout(n: int, segment_offsets):
    """Host-side (trace-time) packing layout for ragged segments.

    segment_offsets: host-known non-decreasing ints, off[0] == 0 and
    off[-1] == n (a traced array raises — static shapes require the
    segmentation to be known at trace time).

    Returns (off, lens, W, valid, src, unpack_src, seg_of_pos) — all
    numpy; W is the padded row width (max segment length).
    """
    off = np.asarray(segment_offsets)
    assert off.ndim == 1 and off.size >= 1, (
        "segment_offsets must be a 1-D sequence [0, ..., n]"
    )
    off = off.astype(np.int64)
    lens = np.diff(off)
    assert off[0] == 0 and off[-1] == n and (lens >= 0).all(), (
        "segment_offsets must be non-decreasing with off[0]=0, off[-1]=n"
    )
    w = int(lens.max()) if lens.size else 0
    col = np.arange(max(w, 1))
    valid = col[None, :] < lens[:, None]  # (S, W)
    src = np.where(valid, off[:-1, None] + col[None, :], 0).astype(np.int32)
    pos = np.arange(n)
    seg_of_pos = np.searchsorted(off, pos, side="right") - 1  # skips empties
    unpack_src = (seg_of_pos * max(w, 1) + (pos - off[seg_of_pos])).astype(
        np.int32
    )
    return off, lens, w, valid, src, unpack_src, seg_of_pos


def _segment_sorted_packed(x: jax.Array, segment_offsets, cfg: SortConfig):
    """Shared segment pipeline: pack ragged segments of 1-D x into a
    padded (S, W) batch (scatter-free gather), run the row-native sort,
    and return (codec, sorted words (S, W), local_perm (S, W), layout).

    Packing rule (DESIGN.md §5): row i holds segment i left-justified;
    columns past the segment length hold (all-ones words, W + j) pads —
    unique per row, above every real payload (local indices < W), so
    they sort last and the per-row capacity bound is untouched.
    """
    n = x.shape[0]
    layout = _segment_layout(n, segment_offsets)
    _, lens, w, valid, src, _, _ = layout
    codec = codec_for(x.dtype, cfg.descending)
    kw = codec.encode(x)
    validj = jnp.asarray(valid)
    srcj = jnp.asarray(src)
    col = jnp.asarray(np.arange(max(w, 1)), jnp.int32)[None, :]
    pkw = tuple(jnp.where(validj, u[srcj], _MAXU) for u in kw)
    pv = jnp.where(validj, col, jnp.int32(w) + col)
    s_orig = lens.size
    plan = resolve_plan(
        max(w, 1), x.dtype, cfg, rows=s_orig, pad_rows=True
    )
    pkw, pv = _pad_rows(pkw, pv, plan)
    skw, sv = _execute_packed(pkw, pv, plan, 2 * max(w, 1), check=cfg.check)
    return codec, tuple(u[:s_orig] for u in skw), sv[:s_orig], layout


def segment_sort(x: jax.Array, segment_offsets,
                 cfg: SortConfig = DEFAULT_CONFIG) -> jax.Array:
    """Sort each segment x[off[i]:off[i+1]] independently, in place.

    Args:
        x: 1-D array of any codec dtype (see :func:`sort`).
        segment_offsets: host-known ints (python ints / numpy / concrete
            array), non-decreasing, off[0] = 0, off[-1] = len(x): the
            padded row width is a static shape.  Empty segments are fine.
        cfg: pipeline knobs (``descending`` sorts every segment
            descending).
    Returns:
        Array of x's shape; one launch for all segments; no element
        crosses a segment boundary (tested).

    Example:
        >>> import jax.numpy as jnp
        >>> from repro.core import bucket_sort
        >>> bucket_sort.segment_sort(jnp.asarray([3, 1, 9, 7, 8]), [0, 2, 5])
        Array([1, 3, 7, 8, 9], dtype=int32)
    """
    assert x.ndim == 1, x.shape
    n = x.shape[0]
    if n == 0:
        _segment_layout(n, segment_offsets)  # still validate offsets
        return x
    codec, skw, _, layout = _segment_sorted_packed(x, segment_offsets, cfg)
    unpack = jnp.asarray(layout[5])
    return codec.decode(tuple(jnp.take(u.reshape(-1), unpack) for u in skw))


def segment_argsort(x: jax.Array, segment_offsets,
                    cfg: SortConfig = DEFAULT_CONFIG) -> jax.Array:
    """Per-segment stable argsort with GLOBAL indices: out[off[i]:off[i+1]]
    is a permutation of [off[i], off[i+1]) and x[out] == segment_sort(x).

    Args/Returns: as :func:`segment_sort`, but an int32 permutation.
    """
    assert x.ndim == 1, x.shape
    n = x.shape[0]
    if n == 0:
        _segment_layout(n, segment_offsets)
        return jnp.arange(0, dtype=jnp.int32)
    _, _, sv, layout = _segment_sorted_packed(x, segment_offsets, cfg)
    off, _, _, _, _, unpack_src, seg_of_pos = layout
    local = jnp.take(sv.reshape(-1), jnp.asarray(unpack_src))
    return jnp.asarray(off[seg_of_pos].astype(np.int32)) + local
