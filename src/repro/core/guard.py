"""Guarded execution: runtime invariant checks + degradation chains.

The paper's headline claim — deterministic regular sampling makes every
bucket capacity a *static guarantee* (``cap = round_up(lp/s_round +
lp/s, 128)``, DESIGN.md §2) — was previously verified only in tests.
This module makes it a production check (``SortConfig.check``) and
gives every fallible site in the engine an explicit recovery story
(DESIGN.md §11):

* ``check='bounds'`` re-verifies the capacity invariant on the actual
  bucket fills of every round: no bucket exceeds its deterministic
  capacity (so no relocated element was dropped and every ``within``
  offset is ``< cap``), and each row's fills sum to the padded row
  length (conservation).
* ``check='full'`` adds output post-conditions: a permutation checksum
  (per-row sum/xor of payloads and key words, input vs output — no
  element dropped or duplicated) and lexicographic sortedness of the
  canonical key words.

Violations raise :class:`SortRuntimeError` naming the plan node and the
invariant — never a silently corrupt result.

The degradation side: :func:`with_retries` (bounded exponential
backoff for transiently-fallible sites), and a bounded in-memory
:func:`degradation_log` fed by :func:`record_degradation` every time a
chain falls back to a slower-but-correct path, mirrored as a
:class:`DegradationWarning` so operators see it without polling.
"""
from __future__ import annotations

import dataclasses
import threading
import time
import warnings

import numpy as np

__all__ = [
    "CHECK_MODES",
    "SortRuntimeError",
    "DegradationWarning",
    "DegradationEvent",
    "record_degradation",
    "degradation_log",
    "clear_degradation_log",
    "with_retries",
    "validate_check",
    "bucket_spine",
    "plan_site",
    "check_bounds",
    "check_full",
    "check_topk",
]

#: Valid values of ``SortConfig.check``.
CHECK_MODES = ("off", "bounds", "full")


class SortRuntimeError(RuntimeError):
    """A runtime invariant of the sort engine was violated.

    Attributes:
        site: where — a plan-node path (e.g.
            ``"SortPlan(rows=1, length=65536, ...)/level0:bucket(...)"``)
            or a named subsystem site (e.g. ``"autotune.measure"``).
        invariant: which guarantee failed, as a short expression
            (e.g. ``"bucket_fill <= cap"``).
        detail: the measured numbers behind the violation.
    """

    def __init__(self, site: str, invariant: str, detail: str = ""):
        self.site = site
        self.invariant = invariant
        self.detail = detail
        msg = f"sort invariant violated at {site}: {invariant}"
        if detail:
            msg += f" — {detail}"
        super().__init__(msg)


class DegradationWarning(UserWarning):
    """A degradation chain fell back to a slower-but-correct path."""


@dataclasses.dataclass(frozen=True)
class DegradationEvent:
    """One recorded fallback/retry step of a degradation chain."""

    site: str
    action: str      # "retry" | "fallback"
    frm: str         # what failed
    to: str          # what the chain moved to
    error: str       # repr of the triggering exception


_LOG_MAX = 256
_log_lock = threading.Lock()
_log: list[DegradationEvent] = []


def record_degradation(site: str, action: str, frm: str, to: str,
                       error: BaseException | str) -> DegradationEvent:
    """Append an event to the bounded degradation log + warn once visibly."""
    err = error if isinstance(error, str) else f"{type(error).__name__}: {error}"
    ev = DegradationEvent(site=site, action=action, frm=frm, to=to, error=err)
    with _log_lock:
        if len(_log) >= _LOG_MAX:
            del _log[0]
        _log.append(ev)
    warnings.warn(
        f"degraded at {site}: {frm} -> {to} ({action}) after {err}",
        DegradationWarning,
        stacklevel=3,
    )
    return ev


def degradation_log() -> tuple[DegradationEvent, ...]:
    """Snapshot of recorded degradation events (most recent last)."""
    with _log_lock:
        return tuple(_log)


def clear_degradation_log() -> None:
    with _log_lock:
        _log.clear()


def with_retries(fn, *, site: str, attempts: int = 3, base_delay: float = 0.05,
                 max_delay: float = 2.0, retry_on=(Exception,),
                 sleep=time.sleep):
    """Call ``fn()`` with bounded retry + exponential backoff.

    Retries up to ``attempts`` total calls on ``retry_on`` exceptions,
    sleeping ``base_delay * 2**k`` (capped at ``max_delay``) between
    them and recording each retry in the degradation log.  The final
    failure re-raises the original exception — callers decide the next
    chain step (fallback, denylist, structured error).
    """
    if attempts < 1:
        raise ValueError(f"attempts must be >= 1, got {attempts}")
    delay = base_delay
    for attempt in range(attempts):
        try:
            return fn()
        except retry_on as e:
            if attempt == attempts - 1:
                raise
            record_degradation(
                site, "retry", f"attempt {attempt + 1}", f"attempt {attempt + 2}", e
            )
            sleep(min(delay, max_delay))
            delay *= 2


def validate_check(check: str) -> None:
    """Raise ValueError unless ``check`` is a valid checked-mode name."""
    if check not in CHECK_MODES:
        raise ValueError(
            f"check must be one of {CHECK_MODES}, got {check!r}")


# ----------------------------------------------------------------------
# Invariant checks (host-side post-conditions on concrete outputs)
# ----------------------------------------------------------------------


def plan_site(plan) -> str:
    """Stable human-readable identity of a plan for error sites."""
    return (f"SortPlan(rows={plan.rows}, length={plan.length}, "
            f"dtype={plan.dtype_name}, impl={plan.impl})")


def bucket_spine(plan) -> list:
    """The chain of bucket nodes the executor collects stats for, in
    stats order: the root's ``bucket_plan`` descent (sample recursions
    run with stats disabled — see ``_run_node``)."""
    nodes = []
    node = plan.root
    while node is not None and node.kind == "bucket":
        nodes.append(node)
        node = node.bucket_plan
    return nodes


def _node_site(plan, level: int, node) -> str:
    return (f"{plan_site(plan)}/level{level}:bucket(rows={node.rows}, "
            f"lp={node.lp}, s_round={node.s_round}, cap={node.cap})")


def check_bounds(plan, stats) -> None:
    """``check='bounds'``: verify the paper's capacity invariant on the
    measured bucket fills of every round.

    Per bucket round (stats entry, matched to the plan's bucket spine):

    * executor/plan capacity agreement (``capacity == node.cap``);
    * ``max bucket fill <= cap`` — the deterministic regular-sampling
      bound; a violation means relocation dropped elements and every
      in-bucket offset ``within`` is no longer ``< cap``;
    * per-row fills sum to the padded row length — conservation: every
      element (including pads) landed in exactly one bucket.

    Raises :class:`SortRuntimeError` naming the plan node + invariant.
    """
    spine = bucket_spine(plan)
    if len(stats) != len(spine):
        raise SortRuntimeError(
            plan_site(plan), "len(stats) == len(bucket_spine)",
            f"executor reported {len(stats)} bucket rounds, plan has "
            f"{len(spine)}")
    for level, (node, st) in enumerate(zip(spine, stats)):
        site = _node_site(plan, level, node)
        cap = int(st["capacity"])
        if cap != node.cap:
            raise SortRuntimeError(
                site, "capacity == plan.cap",
                f"executor ran with capacity {cap}, plan says {node.cap}")
        totals = np.asarray(st["totals"])
        max_fill = int(totals.max()) if totals.size else 0
        if max_fill > cap:
            raise SortRuntimeError(
                site, "bucket_fill <= cap",
                f"max bucket fill {max_fill} exceeds the deterministic "
                f"capacity {cap} (lp={int(st['level_len'])}, "
                f"s_round={int(st['s_round'])}): relocation dropped "
                f"elements / within >= cap")
        lp = int(st["level_len"])
        row_sums = totals.sum(axis=1)
        if totals.size and not (row_sums == lp).all():
            bad = int((row_sums != lp).sum())
            raise SortRuntimeError(
                site, "sum(bucket_fills) == lp",
                f"{bad} row(s) have bucket fills summing to "
                f"{int(row_sums.min())}..{int(row_sums.max())}, expected "
                f"{lp}: elements lost or duplicated in relocation")


def _row_checksums(kw, vals):
    """Per-row (sum, xor) over payloads + per-word sums — order-invariant
    fingerprints for the permutation check."""
    v = np.asarray(vals).astype(np.int64)
    sums = v.sum(axis=1)
    xors = np.bitwise_xor.reduce(v, axis=1)
    wsums = tuple(np.asarray(w).astype(np.uint64).sum(axis=1) for w in kw)
    return sums, xors, wsums


def check_full(plan, in_kw, in_vals, out_kw, out_vals) -> None:
    """``check='full'``: output post-conditions, after :func:`check_bounds`.

    * permutation checksum — per-row sum and xor of the int32 payloads
      and per-row sums of each key word match between input and output
      (order-invariant: catches dropped, duplicated, or corrupted
      elements that conserve bucket counts);
    * sortedness — adjacent canonical key words are lexicographically
      non-decreasing in every row.
    """
    site = f"{plan_site(plan)}/output"
    in_s, in_x, in_w = _row_checksums(in_kw, in_vals)
    out_s, out_x, out_w = _row_checksums(out_kw, out_vals)
    if not (np.array_equal(in_s, out_s) and np.array_equal(in_x, out_x)):
        bad = int(((in_s != out_s) | (in_x != out_x)).sum())
        raise SortRuntimeError(
            site, "payload permutation checksum",
            f"{bad} row(s): output payloads are not a permutation of the "
            f"input payloads (elements dropped or duplicated)")
    for wi, (a, b) in enumerate(zip(in_w, out_w)):
        if not np.array_equal(a, b):
            raise SortRuntimeError(
                site, "key-word permutation checksum",
                f"word {wi}: {int((a != b).sum())} row(s) changed key "
                f"content through the sort")
    ws = [np.asarray(w) for w in out_kw]
    if ws[0].shape[1] > 1:
        gt = np.zeros((ws[0].shape[0], ws[0].shape[1] - 1), dtype=bool)
        eq = np.ones_like(gt)
        for w in ws:
            a, b = w[:, :-1], w[:, 1:]
            gt |= eq & (a > b)
            eq &= a == b
        if gt.any():
            raise SortRuntimeError(
                site, "output sortedness",
                f"{int(gt.sum())} adjacent inversion(s) in the canonical "
                f"key words")


def check_topk(x, vals, idx, k: int, check: str, codec) -> None:
    """Checked-mode post-conditions for top-k (``core/partial_sort``).

    ``'bounds'``: indices lie in the candidate range.  ``'full'`` adds:
    per-row index uniqueness, bitwise ``vals == x[idx]`` agreement, and
    descending sortedness of ``vals`` under the dtype's total order
    (via the descending key codec).
    """
    xs = np.asarray(x)
    if xs.ndim == 1:
        xs = xs[None, :]
    v = np.asarray(vals).reshape(-1, k)
    ix = np.asarray(idx).reshape(-1, k)
    site = f"topk(rows={xs.shape[0]}, n={xs.shape[1]}, k={k})"
    n = xs.shape[1]
    if ((ix < 0) | (ix >= n)).any():
        raise SortRuntimeError(
            site, "0 <= idx < n",
            f"indices outside [0, {n}): "
            f"min={int(ix.min())}, max={int(ix.max())}")
    if check != "full":
        return
    srt = np.sort(ix, axis=1)
    if (srt[:, 1:] == srt[:, :-1]).any():
        raise SortRuntimeError(
            site, "idx unique per row", "duplicate indices returned")
    gathered = np.take_along_axis(xs, ix, axis=1)
    # bitwise agreement (NaN-safe): compare raw bytes, not values
    if gathered.view(np.uint8).tobytes() != v.view(np.uint8).tobytes():
        raise SortRuntimeError(
            site, "vals == x[idx] (bitwise)",
            "returned values disagree with the gathered indices")
    import jax.numpy as jnp  # deferred: keep guard importable early

    words = [np.asarray(w) for w in codec.encode(jnp.asarray(v))]
    if k > 1:
        gt = np.zeros((v.shape[0], k - 1), dtype=bool)
        eq = np.ones_like(gt)
        for w in words:
            a, b = w[:, :-1], w[:, 1:]
            gt |= eq & (a > b)
            eq &= a == b
        if gt.any():
            raise SortRuntimeError(
                site, "vals descending",
                f"{int(gt.sum())} adjacent inversion(s) in top-k values")
