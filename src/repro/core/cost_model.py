"""Analytic per-plan-node cost model: rank schedules WITHOUT running them.

The paper's core property — deterministic regular sampling makes every
bucket capacity a *static guarantee* — has a corollary for tuning: the
cost of a plan is a function of its **geometry**, not of the data.
Bytes moved per pass, compare-exchange counts, radix passes, merge
levels, exchange volume — all are closed-form in the plan fields.  This
module generalizes DESIGN.md §6's two-word data-movement model into a
full walk over the plan IR (:func:`estimate` on
``SortPlan`` / ``TopkPlan`` / ``ShardPlan``), so the autotuner
(``core/autotune.py``) can score the WHOLE candidate space analytically
and measure only the top few (the AttentionEngine/roller policy shape;
the multiway-mergesort analysis arXiv 1702.07961 shows such a
data-movement model ranks GPU sort variants well).

The unit is **HBM byte-equivalents**: one unit = the cost of moving one
byte between HBM and VMEM.  Compute is folded in at ``OP_BYTE_EQUIV``
bytes per compare-unit (a balance-point constant, not a measurement);
interconnect traffic at ``COLLECTIVE_BYTE_WEIGHT`` bytes per byte.
Scores therefore rank plans; they are not wall-time predictions.  The
model's *rank* quality against measured times is what the tests pin
(Spearman over a fixed candidate slice, ``tests/test_cost_model.py``)
and what ``BENCH_sort.json`` records per candidate.

Distribution priors (DESIGN.md §10): the probe's two signals
(``core/probe.priors_for``) shift only the strategy-dependent op terms —
sortedness discounts the merge strategy's compare volume (long runs
mean cheap formation and shallow effective merging), low top-bits
entropy penalizes radix (degenerate digit histograms make the rank
passes skewed).  Geometry terms never depend on data: that is the
paper's determinism, kept.

``COST_MODEL_VERSION`` is persisted with every autotuned store record;
a version bump makes old records a clean cache miss (re-tune, never
misread — mirrors the plan-schema-bump behavior).
"""

from __future__ import annotations

import dataclasses

from repro.core.plan import LevelPlan, ShardPlan, SortPlan, TopkPlan
from repro.core.sort_config import next_pow2

# Bump on ANY change to the constants or formulas below: persisted
# autotune records carry this tag and a mismatch at load is a clean
# re-tune (core/autotune.plan_for / shard_plan_for).
COST_MODEL_VERSION = "cost_model/v1"

# --- model constants (DESIGN.md §10 derives each; calibrated once
# against a measured 12-candidate slice at n=2^18, see the Spearman
# test in tests/test_cost_model.py) ------------------------------------
# One compare-unit (a w-word compare-exchange lane op) costed in HBM
# byte-equivalents: the VPU/HBM balance point of the §6 model.
OP_BYTE_EQUIV = 0.25
# Interconnect bytes are slower than HBM bytes (ICI/NVLink vs HBM BW).
COLLECTIVE_BYTE_WEIGHT = 4.0
# A scatter write costs ~this many gather-write equivalents (DESIGN.md
# §4: serialized RMW vs dense destination-indexed reads).
SCATTER_WRITE_FACTOR = 5.0
# Per-pass per-element radix work: counter update + scan share + rank
# binary searches (kernels/radix.py); the log term is the slot search.
RADIX_PASS_BASE = 3.0
# Splitter ranking compares every element against all s_round-1
# splitters (the _lt_matrix formulation): per-element units per bucket.
RANK_UNITS_PER_BUCKET = 2.0
# Merge-path per-level per-element work: the diagonal binary search is
# amortized across each output block (fraction of log2(T) per element)
# plus the linear merge move.
MERGE_SEARCH_FRACTION = 0.25
MERGE_LEVEL_BASE = 2.0
VMEM_BUDGET_BYTES = 16 * 1024 * 1024
LANE = 128
SUBLANE = 8


@dataclasses.dataclass(frozen=True)
class Priors:
    """Distribution priors for the strategy-dependent op terms.

    ``sortedness`` — fraction of adjacent pairs already in canonical
    order (0.5 = random); ``top_bits_entropy`` — Shannon bits (max 8)
    of the top byte of the most significant canonical word.  Defaults
    are the data-free neutral assumptions (random keys, full entropy);
    ``core/probe.priors_for`` measures both on a concrete sample.
    """

    sortedness: float = 0.5
    top_bits_entropy: float = 8.0


DEFAULT_PRIORS = Priors()


@dataclasses.dataclass(frozen=True)
class CostBreakdown:
    """The estimator's output, one number per cost channel.

    Attributes:
        hbm_bytes: HBM<->VMEM bytes moved across every pass of the plan.
        op_units: compare-unit count (compare-exchanges, radix pass
            work, merge-path searches) across every level.
        collective_bytes: per-device interconnect bytes (shard plans:
            deal + sample gather + c_pair-padded bucket exchange; 0 for
            single-device plans).
        vmem_peak_bytes: largest per-core VMEM working set of any level.
        align_penalty: multiplicative lane/sublane/VMEM-overflow
            penalty (>= 1.0).
        total: the scalar score the autotuner ranks by —
            ``(hbm + OP_BYTE_EQUIV*ops + COLLECTIVE_BYTE_WEIGHT*coll) *
            align_penalty``, in HBM byte-equivalents.
    """

    hbm_bytes: float
    op_units: float
    collective_bytes: float
    vmem_peak_bytes: int
    align_penalty: float
    total: float

    def as_dict(self) -> dict:
        """Plain-dict view (BENCH_sort.json rows record this)."""
        return dataclasses.asdict(self)


def _log2(x: int) -> int:
    return max(next_pow2(x).bit_length() - 1, 0)


def _stages(width: int) -> int:
    """Compare-exchange stages of the full bitonic network on
    ``next_pow2(width)`` elements: L(L+1)/2."""
    lg = _log2(width)
    return lg * (lg + 1) // 2


def local_sort_op_units(
    width: int,
    num_words: int,
    strategy: str,
    radix_bits: int,
    merge_run: int,
    priors: Priors,
) -> float:
    """Per-ELEMENT compare-unit cost of one local sort of ``width``
    (DESIGN.md §10's strategy table).

    bitonic: ``stages(T) * (w+1)`` — data-oblivious, priors never apply.
    radix: ``w * 32/bits`` passes at ``RADIX_PASS_BASE + log2(T)/4``
        units each, scaled up as top-bits entropy drops (degenerate
        digit histograms).
    merge: run formation ``stages(r)`` plus ``log2(T/r)`` merge-path
        levels at ``MERGE_SEARCH_FRACTION*log2(T) + MERGE_LEVEL_BASE``
        units, all ``*(w+1)``, discounted as sortedness rises above 0.5
        (runs pre-exist).
    """
    wfac = num_words + 1  # key words + the payload tiebreak word
    lg = _log2(width)
    if strategy == "radix":
        passes = num_words * (32 // radix_bits)
        per_pass = RADIX_PASS_BASE + lg / 4.0
        entropy = min(max(priors.top_bits_entropy, 0.0), 8.0)
        skew = 2.0 - entropy / 8.0  # 1.0 at full entropy, 2.0 degenerate
        return passes * per_pass * skew
    if strategy == "merge":
        r = min(next_pow2(merge_run), next_pow2(width))
        lr = _log2(r)
        form = _stages(r)
        levels = max(lg - lr, 0)
        merge = levels * (MERGE_SEARCH_FRACTION * lg + MERGE_LEVEL_BASE)
        p = min(max(priors.sortedness, 0.0), 1.0)
        # no discount at/below random (0.5); 0.3x at fully sorted
        discount = 1.0 - 1.4 * max(p - 0.5, 0.0)
        return (form + merge) * wfac * discount
    return _stages(width) * wfac  # bitonic


def _node_vmem(node: LevelPlan, bpe: int) -> int:
    """Per-core VMEM working set of the node's tile sort: block_rows
    tiles of (words + payload), double-buffered.  0 on the xla path
    (no VMEM tiling to model)."""
    if node.block_rows is None:
        return 0
    width = node.tile if node.kind == "bucket" else next_pow2(node.lp)
    return 2 * node.block_rows * width * bpe


def _align_factor(node: LevelPlan) -> float:
    """Lane/sublane alignment penalty of one level (multiplicative)."""
    f = 1.0
    width = node.tile if node.kind == "bucket" else node.lp
    if next_pow2(width) % LANE != 0:
        f *= 1.25  # sub-lane tiles waste vector lanes
    if node.block_rows is not None and node.block_rows < SUBLANE:
        f *= 1.0 + 0.25 * (SUBLANE - node.block_rows) / SUBLANE
    return f


def _estimate_node(
    node: LevelPlan | None, nw: int, priors: Priors
) -> tuple[float, float, int, float]:
    """(hbm_bytes, op_units, vmem_peak, align_penalty) of a level tree."""
    if node is None:
        return 0.0, 0.0, 0, 1.0
    bpe = 4 * (nw + 1)
    if node.kind == "direct":
        e = node.rows * node.lp
        hbm = 2.0 * e * bpe  # one read + one write
        ops = e * local_sort_op_units(
            node.lp, nw, node.strategy, node.radix_bits, node.merge_run,
            priors,
        )
        return hbm, ops, _node_vmem(node, bpe), _align_factor(node)

    e = node.elements          # rows * lp entering the round
    eb = node.bucket_elements  # rows * s_round * cap after relocation
    # Step 2 local tile sort: one read + one write per element.
    hbm = 2.0 * e * bpe
    ops = e * local_sort_op_units(
        node.tile, nw, node.strategy, node.radix_bits, node.merge_run,
        priors,
    )
    # Step 3 sampling: fused = kernel epilogue (free); unfused = one
    # more pass over the sorted tiles.
    if not node.fuse_sampling:
        hbm += e * bpe
    # Steps 6-7 splitter ranking/partition: fused = one read of the
    # tiles; unfused = a ranks pass plus a partition pass.  Ranking
    # compares every element against all s_round-1 splitters (the
    # _lt_matrix formulation) — LINEAR in the bucket count, which is
    # what prices the s knob.
    hbm += (1.0 if node.fuse_ranking else 2.0) * e * bpe
    ops += e * node.s_round * RANK_UNITS_PER_BUCKET * (nw + 1)
    # Step 8 relocation into the dense bucket array, then compaction.
    if node.relocation == "scatter":
        hbm += (e * SCATTER_WRITE_FACTOR + eb) * bpe
    else:
        hbm += (e + eb) * bpe
        ops += eb * (_log2(node.m * node.s_round) + 1)  # source search
    hbm += (eb + e) * bpe  # compaction gather back to dense rows
    vmem = _node_vmem(node, bpe)
    align = _align_factor(node)

    for child in (node.sample_plan, node.bucket_plan):
        ch, co, cv, ca = _estimate_node(child, nw, priors)
        hbm += ch
        ops += co
        vmem = max(vmem, cv)
        align = max(align, ca)
    return hbm, ops, vmem, align


def _finish(
    hbm: float, ops: float, coll: float, vmem: int, align: float
) -> CostBreakdown:
    if vmem > VMEM_BUDGET_BYTES:
        align *= vmem / VMEM_BUDGET_BYTES  # spill: re-tile overhead
    total = (hbm + OP_BYTE_EQUIV * ops + COLLECTIVE_BYTE_WEIGHT * coll)
    return CostBreakdown(
        hbm_bytes=hbm,
        op_units=ops,
        collective_bytes=coll,
        vmem_peak_bytes=vmem,
        align_penalty=align,
        total=total * align,
    )


def _estimate_sort(plan: SortPlan, priors: Priors) -> CostBreakdown:
    hbm, ops, vmem, align = _estimate_node(plan.root, plan.num_words, priors)
    return _finish(hbm, ops, 0.0, vmem, align)


def _estimate_topk(plan: TopkPlan, priors: Priors) -> CostBreakdown:
    nw, bpe = _topk_words(plan), 4 * (_topk_words(plan) + 1)
    if plan.length <= plan.direct_max:
        e = max(plan.rows, 1) * next_pow2(plan.length)
        ops = e * local_sort_op_units(
            plan.length, nw, plan.strategy, plan.radix_bits,
            plan.merge_run, priors,
        )
        return _finish(2.0 * e * bpe, ops, 0.0, 0, 1.0)
    e = plan.elements
    ec = plan.candidate_elements
    # tile sort + threshold pass + candidate pack + candidate sort
    hbm = 2.0 * e * bpe + e * bpe + (e + ec) * bpe + 2.0 * ec * bpe
    ops = e * local_sort_op_units(
        plan.tile, nw, plan.strategy, plan.radix_bits, plan.merge_run,
        priors,
    )
    ops += ec * local_sort_op_units(
        plan.ccap, nw, plan.strategy, plan.radix_bits, plan.merge_run,
        priors,
    )
    vmem = 0
    if plan.block_rows is not None:
        vmem = 2 * plan.block_rows * plan.tile * bpe
    return _finish(hbm, ops, 0.0, vmem, 1.0)


def _topk_words(plan: TopkPlan) -> int:
    # TopkPlan predates num_words as a field; one word is the common
    # case (topk encodes through the descending codec of the dtype).
    return getattr(plan, "num_words", 1)


def _estimate_shard(plan: ShardPlan, priors: Priors) -> CostBreakdown:
    bpe = 4 * (plan.num_words + 1)
    hbm = ops = 0.0
    vmem, align = 0, 1.0
    # The dealt/bucket phases sort concatenations of d sorted runs —
    # structurally high sortedness regardless of the input data.
    piecewise = dataclasses.replace(
        priors, sortedness=max(priors.sortedness, 0.75)
    )
    for name, pri in (
        ("run_plan", priors),
        ("dealt_plan", piecewise),
        ("sample_plan", piecewise),
        ("bucket_plan", piecewise),
    ):
        sub: SortPlan = getattr(plan, name)
        b = _estimate_sort(sub, pri)
        hbm += b.hbm_bytes
        ops += b.op_units
        vmem = max(vmem, b.vmem_peak_bytes)
        align = max(align, b.align_penalty)
    # Per-device interconnect volume: the deal all_to_all (n_pad), the
    # sample gather (d*s_loc), and the c_pair-PADDED bucket exchange —
    # padding waste (d*c_pair vs b_t) is charged at full price, which
    # is what lets the tuner trade pair_align against message size.
    coll = float(plan.collective_elements) * bpe
    if plan.c_pair % LANE != 0:
        align = max(align, 1.02)  # unaligned exchange messages
    return _finish(hbm, ops, coll, vmem, align)


def estimate(plan, priors: Priors | None = None) -> CostBreakdown:
    """Analytic cost of a plan node — the autotuner's ranking score.

    Deterministic and pure: equal ``(plan, priors)`` give equal
    breakdowns; cost is positive and monotone in n for fixed config
    geometry (property-tested in ``tests/test_cost_model.py``).

    Args:
        plan: a :class:`~repro.core.plan.SortPlan`,
            :class:`~repro.core.plan.TopkPlan` or
            :class:`~repro.core.plan.ShardPlan`.
        priors: optional distribution priors
            (``core/probe.priors_for``); ``None`` = neutral
            :data:`DEFAULT_PRIORS`.
    Returns:
        A :class:`CostBreakdown`; rank candidates by ``.total``.
    Raises:
        TypeError: for an unknown plan type.

    Example:
        >>> from repro.core.cost_model import estimate
        >>> from repro.core.plan import build_plan
        >>> from repro.core.sort_config import SortConfig
        >>> cfg = SortConfig(tile=256, s=16, direct_max=512, impl="xla")
        >>> small = estimate(build_plan(10_000, "int32", cfg))
        >>> big = estimate(build_plan(80_000, "int32", cfg))
        >>> (small.total > 0, big.total > small.total)
        (True, True)
    """
    priors = DEFAULT_PRIORS if priors is None else priors
    if isinstance(plan, SortPlan):
        return _estimate_sort(plan, priors)
    if isinstance(plan, TopkPlan):
        return _estimate_topk(plan, priors)
    if isinstance(plan, ShardPlan):
        return _estimate_shard(plan, priors)
    raise TypeError(
        f"estimate() takes a SortPlan, TopkPlan or ShardPlan, got "
        f"{type(plan).__name__}"
    )
