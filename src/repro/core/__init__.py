# The paper's primary contribution: deterministic sample sort (GPU BUCKET
# SORT, Dehne & Zaboli 2010) adapted to TPU — single-device Algorithm 1,
# the batched/segmented layer (many independent sorts per launch), the
# multi-chip/pod distributed variant, partial (top-k) sort, and the
# baselines the paper compares against.

from repro.core.bucket_sort import (
    argsort,
    argsort_batched,
    segment_argsort,
    segment_sort,
    sort,
    sort_batched,
    sort_batched_with_stats,
    sort_kv,
    sort_kv_batched,
    sort_with_stats,
)
from repro.core.distributed_sort import DistSortSpec, make_sharded_sort, sorted_shard
from repro.core.key_codec import SUPPORTED_DTYPES, KeyCodec, codec_for
from repro.core.partial_sort import topk, topk_batched
from repro.core.sort_config import DEFAULT_CONFIG, PAPER_CONFIG, SortConfig

__all__ = [
    "argsort",
    "argsort_batched",
    "segment_argsort",
    "segment_sort",
    "sort",
    "sort_batched",
    "sort_batched_with_stats",
    "sort_kv",
    "sort_kv_batched",
    "sort_with_stats",
    "topk",
    "topk_batched",
    "KeyCodec",
    "codec_for",
    "SUPPORTED_DTYPES",
    "SortConfig",
    "DEFAULT_CONFIG",
    "PAPER_CONFIG",
    "DistSortSpec",
    "make_sharded_sort",
    "sorted_shard",
]
