# The paper's primary contribution: deterministic sample sort (GPU BUCKET
# SORT, Dehne & Zaboli 2010) adapted to TPU — single-device Algorithm 1,
# the multi-chip/pod distributed variant, partial (top-k) sort, and the
# baselines the paper compares against.

from repro.core.bucket_sort import argsort, sort, sort_kv, sort_with_stats
from repro.core.distributed_sort import DistSortSpec, make_sharded_sort, sorted_shard
from repro.core.partial_sort import topk
from repro.core.sort_config import DEFAULT_CONFIG, PAPER_CONFIG, SortConfig

__all__ = [
    "argsort",
    "sort",
    "sort_kv",
    "sort_with_stats",
    "topk",
    "SortConfig",
    "DEFAULT_CONFIG",
    "PAPER_CONFIG",
    "DistSortSpec",
    "make_sharded_sort",
    "sorted_shard",
]
