# The paper's primary contribution: deterministic sample sort (GPU BUCKET
# SORT, Dehne & Zaboli 2010) adapted to TPU — single-device Algorithm 1,
# the batched/segmented layer (many independent sorts per launch), the
# multi-chip/pod distributed variant, partial (top-k) sort, and the
# baselines the paper compares against.

# NOTE: the tuning and probing entries themselves stay namespaced
# (repro.core.autotune.autotune, repro.core.probe.probe,
# repro.core.cost_model.estimate) — binding the function name here
# would shadow the submodule (or read like it does).
from repro.core.autotune import (
    AutotuneResult,
    CandidateScore,
    load_plan,
    load_shard_plan,
    plan_for,
    save_plan,
    save_shard_plan,
    shard_plan_for,
)
from repro.core.cost_model import COST_MODEL_VERSION, CostBreakdown, Priors
from repro.core.bucket_sort import (
    argsort,
    argsort_batched,
    resolve_plan,
    segment_argsort,
    segment_sort,
    sort,
    sort_batched,
    sort_batched_with_stats,
    sort_kv,
    sort_kv_batched,
    sort_planned,
    sort_with_stats,
)
from repro.core.distributed_sort import DistSortSpec, make_sharded_sort, sorted_shard
from repro.core.faults import FaultInjected
from repro.core.guard import (
    CHECK_MODES,
    DegradationEvent,
    DegradationWarning,
    SortRuntimeError,
    clear_degradation_log,
    degradation_log,
)
from repro.core.key_codec import SUPPORTED_DTYPES, KeyCodec, codec_for
from repro.core.partial_sort import topk, topk_batched
from repro.core.probe import probed_config, recommend_strategy
from repro.core.plan import (
    LevelPlan,
    ShardPlan,
    SortPlan,
    TopkPlan,
    build_plan,
    build_shard_plan,
    build_topk_plan,
    build_words_plan,
    plan_from_dict,
    plan_to_dict,
    shard_geometry,
    shard_plan_from_dict,
    shard_plan_to_dict,
)
from repro.core.sort_config import DEFAULT_CONFIG, PAPER_CONFIG, SortConfig

__all__ = [
    "argsort",
    "argsort_batched",
    "segment_argsort",
    "segment_sort",
    "sort",
    "sort_batched",
    "sort_batched_with_stats",
    "sort_kv",
    "sort_kv_batched",
    "sort_planned",
    "sort_with_stats",
    "topk",
    "topk_batched",
    "KeyCodec",
    "codec_for",
    "SUPPORTED_DTYPES",
    "SortConfig",
    "DEFAULT_CONFIG",
    "PAPER_CONFIG",
    "SortPlan",
    "LevelPlan",
    "TopkPlan",
    "build_plan",
    "build_topk_plan",
    "build_words_plan",
    "plan_from_dict",
    "plan_to_dict",
    "resolve_plan",
    "probed_config",
    "recommend_strategy",
    "AutotuneResult",
    "CandidateScore",
    "CostBreakdown",
    "Priors",
    "COST_MODEL_VERSION",
    "plan_for",
    "load_plan",
    "save_plan",
    "ShardPlan",
    "build_shard_plan",
    "shard_geometry",
    "shard_plan_from_dict",
    "shard_plan_to_dict",
    "shard_plan_for",
    "load_shard_plan",
    "save_shard_plan",
    "DistSortSpec",
    "make_sharded_sort",
    "sorted_shard",
    "CHECK_MODES",
    "DegradationEvent",
    "DegradationWarning",
    "FaultInjected",
    "SortRuntimeError",
    "clear_degradation_log",
    "degradation_log",
]
