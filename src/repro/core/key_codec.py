"""Order-preserving key codecs: any supported dtype -> sortable uint32 words.

The whole sort engine (kernels + bucket pipeline) operates on tuples of
canonical **uint32 key words, most-significant word first**, compared
lexicographically with the int32 payload as the final tiebreak word.  A
:class:`KeyCodec` is the bridge between a user dtype and that canonical
domain: an order-preserving bijective encoding

    encode :  x  ->  (w_0, ..., w_{num_words-1})   (uint32 words)
    decode :  words -> x                            (exact inverse)

such that ``x < y`` in the dtype's total order **iff** ``encode(x) <
encode(y)`` lexicographically as unsigned words.  See DESIGN.md §6 for
the encoding tables and the two-word compare cost model.

Encodings (all classic radix-sort transforms):

  ==========  =====  =====================================================
  dtype       words  transform (per 32-bit word)
  ==========  =====  =====================================================
  uint32      1      identity
  int32       1      bitcast; flip sign bit (``^ 0x8000_0000``)
  float32     1      bitcast; sign bit set -> ``~u`` else ``u | SIGN``
  uint64      2      split into (hi, lo) uint32
  int64       2      flip sign bit of hi, split
  float64     2      64-bit float flip applied across (hi, lo), split
  bool        1      widen to uint32 (False=0 < True=1)
  u/int8,16   1      widen to u/int32, then the 32-bit transform
  bf16, f16   1      upcast to float32 (exact), then the float32 flip
  ==========  =====  =====================================================

The float transforms induce the IEEE-754 **total order**
``-NaN < -inf < ... < -0.0 < +0.0 < ... < +inf < +NaN`` — which places
``np.nan`` (a positive quiet NaN) last, matching ``jnp.sort`` /
``np.sort`` (see DESIGN.md §6 for why the orders agree on real inputs).

``descending=True`` is a *codec-level* complement: every encoded word is
inverted (``~w``), an order-reversing bijection of the canonical domain.
Payloads are never complemented, so equal keys still tie-break by
original index and descending sorts stay stable — matching
``jnp.sort(x, descending=True)`` / ``jnp.argsort(..., descending=True,
stable=True)``.

64-bit dtypes require x64 mode (``jax.config.update("jax_enable_x64",
True)`` or the ``jax.experimental.enable_x64()`` context manager); the
codec raises a clear error otherwise.  The 64 <-> 2x32 split uses
``lax.bitcast_convert_type``'s trailing-dimension form, so no 64-bit
arithmetic is emitted — only the input/output arrays themselves are
64-bit.

Example (doctested)::

    >>> import jax.numpy as jnp
    >>> from repro.core.key_codec import codec_for
    >>> c = codec_for(jnp.float32)
    >>> words = c.encode(jnp.asarray([1.5, -2.0, 0.0], jnp.float32))
    >>> len(words), words[0].dtype
    (1, dtype('uint32'))
    >>> c.decode(words)
    Array([ 1.5, -2. ,  0. ], dtype=float32)
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

_SIGN = jnp.uint32(0x80000000)

#: dtypes with a codec, grouped by canonical word count.
ONE_WORD_DTYPES = (
    "uint32", "int32", "float32",
    "bfloat16", "float16",
    "int16", "int8", "uint16", "uint8", "bool",
)
TWO_WORD_DTYPES = ("uint64", "int64", "float64")
SUPPORTED_DTYPES = ONE_WORD_DTYPES + TWO_WORD_DTYPES


def _require_x64(name: str) -> None:
    if not jax.config.jax_enable_x64:
        raise TypeError(
            f"{name} keys require x64 mode: enable it globally with "
            'jax.config.update("jax_enable_x64", True) or locally with '
            "the jax.experimental.enable_x64() context manager"
        )


def _flip_f32(u):
    """uint32 bitcast of a float32 -> totally-ordered uint32."""
    return jnp.where((u & _SIGN) != 0, ~u, u | _SIGN)


def _unflip_f32(u):
    return jnp.where((u & _SIGN) != 0, u & ~_SIGN, ~u)


def _split64(x):
    """(hi, lo) uint32 words of a 64-bit array, via the trailing-dim
    bitcast (little-endian word order: index 0 is the LOW word)."""
    w = jax.lax.bitcast_convert_type(x, jnp.uint32)  # (..., 2) = [lo, hi]
    return w[..., 1], w[..., 0]


def _join64(hi, lo, dtype):
    w = jnp.stack([lo, hi], axis=-1)
    return jax.lax.bitcast_convert_type(w, dtype)


@dataclasses.dataclass(frozen=True)
class KeyCodec:
    """Order-preserving bijection between a user dtype and uint32 words.

    Attributes:
        dtype_name: canonical dtype name (e.g. ``"float64"``).
        num_words: uint32 words per key (1 for <= 32-bit, 2 for 64-bit).
        descending: if True, every encoded word is complemented so that
            ascending canonical order == descending user order.

    Hashable and trace-time static: derive it once per call site with
    :func:`codec_for` and close over it.
    """

    dtype_name: str
    num_words: int
    descending: bool = False

    @property
    def dtype(self):
        """The user-facing jnp dtype this codec encodes."""
        return jnp.dtype(self.dtype_name)

    # -- encode ---------------------------------------------------------

    def encode(self, x: jax.Array) -> tuple[jax.Array, ...]:
        """Map ``x`` (any shape, self.dtype) to canonical uint32 words.

        Args:
            x: array of ``self.dtype``.
        Returns:
            Tuple of ``num_words`` uint32 arrays of x's shape, most
            significant word first; lexicographic unsigned order of the
            tuples == the dtype's total order (reversed if descending).
        """
        dt = jnp.dtype(x.dtype)
        assert dt == self.dtype, (dt, self.dtype)
        name = self.dtype_name
        if name in ("bfloat16", "float16"):
            x = x.astype(jnp.float32)
            name = "float32"
        elif name in ("int8", "int16"):
            x = x.astype(jnp.int32)
            name = "int32"
        elif name in ("uint8", "uint16", "bool"):
            x = x.astype(jnp.uint32)
            name = "uint32"

        if name == "uint32":
            words = (x,)
        elif name == "int32":
            words = (jax.lax.bitcast_convert_type(x, jnp.uint32) ^ _SIGN,)
        elif name == "float32":
            words = (_flip_f32(jax.lax.bitcast_convert_type(x, jnp.uint32)),)
        elif name == "uint64":
            _require_x64(name)
            words = _split64(x)
        elif name == "int64":
            _require_x64(name)
            hi, lo = _split64(x)
            words = (hi ^ _SIGN, lo)
        elif name == "float64":
            _require_x64(name)
            hi, lo = _split64(x)
            neg = (hi & _SIGN) != 0
            words = (
                jnp.where(neg, ~hi, hi | _SIGN),
                jnp.where(neg, ~lo, lo),
            )
        else:  # pragma: no cover - codec_for validates
            raise TypeError(f"unsupported sort key dtype {self.dtype_name}")
        if self.descending:
            words = tuple(~w for w in words)
        return words

    # -- decode ---------------------------------------------------------

    def decode(self, words: tuple[jax.Array, ...]) -> jax.Array:
        """Exact inverse of :meth:`encode`.

        Args:
            words: tuple of ``num_words`` uint32 arrays (msw first).
        Returns:
            Array of ``self.dtype`` with ``decode(encode(x)) == x``.
        """
        assert len(words) == self.num_words, (len(words), self.num_words)
        if self.descending:
            words = tuple(~w for w in words)
        name = self.dtype_name
        if name in ("bfloat16", "float16", "float32"):
            f32 = jax.lax.bitcast_convert_type(
                _unflip_f32(words[0]), jnp.float32
            )
            return f32.astype(self.dtype)
        if name in ("int8", "int16", "int32"):
            i32 = jax.lax.bitcast_convert_type(words[0] ^ _SIGN, jnp.int32)
            return i32.astype(self.dtype)
        if name in ("uint8", "uint16", "uint32"):
            return words[0].astype(self.dtype)
        if name == "bool":
            return words[0] != 0
        hi, lo = words
        _require_x64(name)
        if name == "uint64":
            return _join64(hi, lo, jnp.uint64)
        if name == "int64":
            return _join64(hi ^ _SIGN, lo, jnp.int64)
        if name == "float64":
            pos = (hi & _SIGN) != 0  # encoded msb set <=> original >= +0.0
            return _join64(
                jnp.where(pos, hi & ~_SIGN, ~hi),
                jnp.where(pos, lo, ~lo),
                jnp.float64,
            )
        raise TypeError(f"unsupported sort key dtype {name}")


def codec_for(dtype, descending: bool = False) -> KeyCodec:
    """Build the :class:`KeyCodec` for a dtype.

    Args:
        dtype: anything ``jnp.dtype`` accepts (``jnp.float64``,
            ``"int64"``, ``np.int32``, an array's ``.dtype``, ...).
        descending: complement the encoding so canonical-ascending order
            == user-descending order (stable: payload ties untouched).
    Returns:
        A hashable, trace-time-static ``KeyCodec``.
    Raises:
        TypeError: for dtypes without a codec.
    """
    name = jnp.dtype(dtype).name
    if name in ONE_WORD_DTYPES:
        return KeyCodec(name, 1, descending)
    if name in TWO_WORD_DTYPES:
        return KeyCodec(name, 2, descending)
    raise TypeError(
        f"unsupported sort key dtype {name}; supported: {SUPPORTED_DTYPES}"
    )
