"""Configuration for the deterministic sample sort (GPU BUCKET SORT on TPU)."""

from __future__ import annotations

import dataclasses


def next_pow2(x: int) -> int:
    p = 1
    while p < x:
        p *= 2
    return p


def round_up(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult


@dataclasses.dataclass(frozen=True)
class SortConfig:
    """Knobs of Algorithm 1, adapted to TPU.

    tile: VMEM tile size T (paper: n/m = 2K items per SM shared memory).
        Power of two; multiple of 128 for lane alignment on real TPU.
    s: samples per tile == max buckets per round (paper: s = 64, Fig. 3).
    direct_max: arrays up to this length are bitonic-sorted directly in a
        single tile instead of going through a bucket round.
    impl: "pallas" (kernels) | "xla" (pure-jnp reference path) | None=auto.
    interpret: Pallas interpret mode (None = auto: True off-TPU).
    """

    tile: int = 4096
    s: int = 64
    direct_max: int = 8192
    impl: str | None = None
    interpret: bool | None = None

    def __post_init__(self):
        assert self.tile >= 2 and self.tile & (self.tile - 1) == 0, self.tile
        assert self.s >= 2 and self.s & (self.s - 1) == 0, self.s
        assert self.s <= self.tile and self.tile % self.s == 0
        assert self.direct_max >= self.tile
        assert self.impl in (None, "pallas", "xla")


# Paper default: s = 64 (Fig. 3 sweep), 2K-item tiles on 16KB shared memory.
# TPU default: larger VMEM => larger tiles.
PAPER_CONFIG = SortConfig(tile=2048, s=64, direct_max=4096)
DEFAULT_CONFIG = SortConfig()
