"""Configuration for the deterministic sample sort (GPU BUCKET SORT on TPU)."""

from __future__ import annotations

import dataclasses


def next_pow2(x: int) -> int:
    """Smallest power of two >= x (trace-time int; next_pow2(0) == 1).

    Args:
        x: non-negative python int.
    Returns:
        The next power of two, as a python int.
    """
    p = 1
    while p < x:
        p *= 2
    return p


def round_up(x: int, mult: int) -> int:
    """Round x up to the nearest multiple of mult (trace-time ints).

    Args:
        x: non-negative python int.
        mult: positive python int.
    Returns:
        Smallest multiple of ``mult`` >= x, as a python int.
    """
    return ((x + mult - 1) // mult) * mult


@dataclasses.dataclass(frozen=True)
class SortConfig:
    """Knobs of Algorithm 1, adapted to TPU (layout: DESIGN.md §3-§4).

    tile: VMEM tile size T (paper: n/m = 2K items per SM shared memory).
        Power of two; multiple of 128 for lane alignment on real TPU.
    s: samples per tile == max buckets per round (paper: s = 64, Fig. 3).
    direct_max: arrays up to this length are bitonic-sorted directly in a
        single tile instead of going through a bucket round.
    impl: "pallas" (kernels) | "xla" (pure-jnp reference path) | None=auto.
    interpret: Pallas interpret mode (None = auto: True off-TPU).
    block_rows: tiles sorted per grid program in the row-blocked bitonic
        kernel.  None = auto-pick the largest power-of-two divisor of the
        tile count that fills the VMEM budget; an explicit value must be
        a power of two and acts as an UPPER BOUND — recursion levels
        whose tile count it does not divide clamp down to the largest
        power-of-two divisor (bitonic.effective_block_rows).
    fuse_sampling: emit Step 3's equidistant samples from the tile-sort
        kernel epilogue instead of a separate gather over the sorted
        tiles (one fewer HBM read).
    fuse_ranking: use the fused Step 6+7 splitter-partition epilogue
        (ranks + bucket counts in one read) instead of the standalone
        ranks kernel.
    relocation: "gather" (default) computes the SOURCE index of every
        destination slot and relocates/compacts with `take` — no
        scatters anywhere on the hot path (DESIGN.md §4).  "scatter" is
        the legacy destination-scatter formulation, kept as a reference
        for tests and benchmarks.
    descending: sort every key sequence in DESCENDING order.  A pure
        codec-level switch (DESIGN.md §6): keys are encoded with the
        order-reversing complement codec and the pipeline runs
        unchanged, so descending costs nothing and stays stable (equal
        keys keep their input order, matching
        ``jnp.sort(x, descending=True)``).  Ignored by ``topk`` (top-k
        is descending by definition) and by the ``*_with_stats`` bound
        introspection (bounds are order-agnostic).
    plan: how the static schedule (``core/plan.SortPlan``) is obtained
        (DESIGN.md §7):
          * ``"default"``  — built directly from this config;
          * ``"autotune"`` — the measured-best plan from
            ``core/autotune`` (persistent on-disk cache keyed by
            (shape, dtype, backend, cfg-fingerprint); the first miss
            runs the tuning search and records the winner);
          * any other string — a path to a plan file saved by
            ``autotune.save_plan``; its signature must match the call.
    strategy: local-sort algorithm for the tile/direct sorts (DESIGN.md
        §8).  "bitonic" (default) is the paper's branch-free network;
        "radix" is an LSD radix rank-gather over the canonical uint32
        key words (scatter-free, stable); "merge" forms sorted runs and
        merges them pairwise with merge-path diagonal partitioning
        (exploits pre-sorted input).  All three produce the identical
        stable order (tested); the planner carries the choice per level
        and ``core/autotune`` searches across strategies.  A cheap
        data-distribution probe (``core/probe.py``) can pick this knob
        from a concrete input sample without running the tuner.
    radix_bits: digit width of the radix strategy, in {1, 2, 4} bits
        (4 = 16 digits per pass, 8 passes per 32-bit key word).  Only
        consulted when ``strategy == "radix"``.
    merge_run: initial sorted-run length of the merge strategy, a power
        of two >= 2 (runs are formed with the bitonic network, then
        pairwise-merged up to the tile width).  Only consulted when
        ``strategy == "merge"``.
    row_pad: batch-aware block_rows auto-pick (DESIGN.md §5).  The
        batched entry points (``sort_batched``, ``segment_sort``) pad
        the row count up to a multiple of this power of two before
        entering the row-blocked kernels, so ``auto_block_rows`` always
        finds a divisor >= row_pad and every compare-exchange runs as a
        dense (>= 8-sublane) vector op even for odd batch sizes.  Only
        applied on the pallas path (it is pure overhead for the xla
        reference path); 1 disables.  Pad rows are all-pad (MAXU keys),
        obey the same capacity bound, and are sliced off on exit.
    check: runtime invariant checking (``core/guard.py``, DESIGN.md
        §11).  ``"off"`` (default) runs unguarded.  ``"bounds"``
        verifies the paper's deterministic capacity invariant on every
        bucket round of every call — no bucket fill exceeds the static
        ``cap`` (so relocation dropped nothing and ``within < cap``)
        and per-row fills conserve the padded row length.  ``"full"``
        adds output post-conditions: permutation checksums (payloads
        and key words, input vs output) and canonical-word sortedness.
        Violations raise ``guard.SortRuntimeError`` naming the plan
        node and invariant.  A call-time knob: it is EXCLUDED from the
        config fingerprint (``plan.config_fingerprint``), so checked
        and unchecked runs share plan-cache entries.
    """

    tile: int = 4096
    s: int = 64
    direct_max: int = 8192
    impl: str | None = None
    interpret: bool | None = None
    block_rows: int | None = None
    fuse_sampling: bool = True
    fuse_ranking: bool = True
    relocation: str = "gather"
    descending: bool = False
    row_pad: int = 8
    plan: str = "default"
    strategy: str = "bitonic"
    radix_bits: int = 4
    merge_run: int = 512
    check: str = "off"

    def __post_init__(self):
        # Field-by-field validation with errors that NAME the offending
        # field — a bad knob must fail here, at construction, not as a
        # shape error deep inside a kernel spec.
        def _pow2(name, v, lo):
            if not (isinstance(v, int) and v >= lo and v & (v - 1) == 0):
                raise ValueError(
                    f"SortConfig.{name} must be a power of two >= {lo}, "
                    f"got {v!r}"
                )

        _pow2("tile", self.tile, 2)
        _pow2("s", self.s, 2)
        if self.s > self.tile:
            raise ValueError(
                f"SortConfig.s ({self.s}) must not exceed SortConfig.tile "
                f"({self.tile}): s samples are drawn per tile"
            )
        if self.tile % self.s != 0:
            raise ValueError(
                f"SortConfig.tile ({self.tile}) must be a multiple of "
                f"SortConfig.s ({self.s})"
            )
        if self.direct_max < self.tile:
            raise ValueError(
                f"SortConfig.direct_max ({self.direct_max}) must be >= "
                f"SortConfig.tile ({self.tile})"
            )
        if self.impl not in (None, "pallas", "xla"):
            raise ValueError(
                f'SortConfig.impl must be None, "pallas" or "xla", '
                f"got {self.impl!r}"
            )
        if self.block_rows is not None:
            _pow2("block_rows", self.block_rows, 1)
        if self.relocation not in ("gather", "scatter"):
            raise ValueError(
                f'SortConfig.relocation must be "gather" or "scatter", '
                f"got {self.relocation!r}"
            )
        _pow2("row_pad", self.row_pad, 1)
        if self.strategy not in ("bitonic", "radix", "merge"):
            raise ValueError(
                'SortConfig.strategy must be "bitonic", "radix" or '
                f'"merge", got {self.strategy!r}'
            )
        if self.radix_bits not in (1, 2, 4):
            raise ValueError(
                f"SortConfig.radix_bits must be 1, 2 or 4, got "
                f"{self.radix_bits!r}"
            )
        _pow2("merge_run", self.merge_run, 2)
        if not (isinstance(self.plan, str) and self.plan):
            raise ValueError(
                'SortConfig.plan must be "default", "autotune", or a '
                f"plan-file path, got {self.plan!r}"
            )
        if self.check not in ("off", "bounds", "full"):
            raise ValueError(
                'SortConfig.check must be "off", "bounds" or "full", '
                f"got {self.check!r}"
            )


# Paper default: s = 64 (Fig. 3 sweep), 2K-item tiles on 16KB shared memory.
# TPU default: larger VMEM => larger tiles.
PAPER_CONFIG = SortConfig(tile=2048, s=64, direct_max=4096)
DEFAULT_CONFIG = SortConfig()
