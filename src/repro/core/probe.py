"""Cheap input-distribution probe for picking a local-sort strategy.

The hybrid strategy dispatch (DESIGN.md §8) leaves a choice the planner
cannot make from ``(shape, dtype, config)`` alone: which local-sort
algorithm fits the DATA.  The GPU sorting survey (arXiv 1709.02520) and
the parallel-sort comparison (arXiv 1511.03404) give the decision
structure — merge paths win when the input already contains long sorted
runs; radix ranking wins on narrow integer keys with enough digit
entropy to spread buckets; otherwise the branch-free bitonic network is
the robust default.  This module measures exactly those two signals on
a small sample and picks the strategy WITHOUT running the autotuner:

  * ``sortedness`` — fraction of adjacent element pairs already in
    canonical order, measured over a few evenly-spaced CONTIGUOUS
    chunks (contiguity matters: runs are a neighbourhood property, and
    a scattered sample would destroy them);
  * ``top_bits_entropy`` — Shannon entropy (bits, max 8) of the top
    8 bits of the canonical most-significant key word; near-zero means
    the leading radix passes would be no-ops over a constant digit
    (all-dup / tiny-range inputs) while comparison sorts exit early.

The probe needs CONCRETE values: it runs on the host, off the trace.
Passing a tracer raises TypeError — a data-dependent strategy cannot be
chosen inside ``jit`` without violating the static-plan discipline
(DESIGN.md §7).  Intended use is ahead-of-time::

    cfg = probe.probed_config(x_sample, SortConfig())
    y = bucket_sort.sort(x, cfg)     # plan carries the probed strategy

Thresholds (validated in tests/test_strategy.py and the
``--suite strategies`` benchmark):

  * sortedness >= 0.9          -> "merge"  (long runs dominate; the
    nearly-sorted suite crosses ~0.98, random data sits near 0.5);
  * one-word keys, n >= 2^19, entropy >= 2 bits -> "radix" (narrow
    keys, enough digit spread, and n large enough that the rank
    passes amortize);
  * otherwise                  -> "bitonic".
"""

from __future__ import annotations

import dataclasses

import jax
import numpy as np

from repro.core.key_codec import codec_for
from repro.core.sort_config import DEFAULT_CONFIG, SortConfig

# Decision thresholds (module-level so tests/docs can reference them).
SORTEDNESS_MERGE_THRESHOLD = 0.9
ENTROPY_RADIX_THRESHOLD_BITS = 2.0
RADIX_MIN_N = 1 << 19


def _require_concrete(x) -> None:
    if isinstance(x, jax.core.Tracer):
        raise TypeError(
            "probe() needs concrete values: a data-dependent strategy "
            "cannot be picked inside jit (the plan must stay static, "
            "DESIGN.md §7).  Probe a host-side sample ahead of time."
        )


def probe(x, *, sample_size: int = 4096, num_chunks: int = 16,
          descending: bool = False) -> dict:
    """Measure the two strategy signals on a small sample of ``x``.

    Args:
        x: 1-D array (any codec dtype) — concrete values only.
        sample_size: total elements inspected (evenly-spaced contiguous
            chunks; the whole array when it is small).
        num_chunks: number of contiguous chunks the sample is split
            into.
        descending: measure sortedness in the descending canonical
            order (matches ``SortConfig.descending``).
    Returns:
        dict with ``sortedness`` (float in [0, 1]), ``top_bits_entropy``
        (float bits in [0, 8]), ``n`` and ``num_words``.
    """
    _require_concrete(x)
    codec = codec_for(x.dtype, descending)
    n = int(np.asarray(x.shape[0]))
    if n == 0:
        return dict(sortedness=1.0, top_bits_entropy=0.0, n=0,
                    num_words=codec.num_words)
    sample_size = min(sample_size, n)
    chunk = max(sample_size // max(num_chunks, 1), 2)
    xs = np.asarray(x)
    chunks = []
    for i in range(num_chunks):
        start = (i * max(n - chunk, 0)) // max(num_chunks - 1, 1)
        chunks.append(xs[start:start + chunk])
        if start + chunk >= n:
            break
    import jax.numpy as jnp

    in_order = 0
    pairs = 0
    top = []
    for c in chunks:
        if c.size == 0:
            continue
        msw = np.asarray(codec.encode(jnp.asarray(c))[0], dtype=np.uint64)
        if msw.size >= 2:
            in_order += int(np.sum(msw[:-1] <= msw[1:]))
            pairs += msw.size - 1
        top.append(msw >> 24)
    sortedness = (in_order / pairs) if pairs else 1.0
    hist = np.bincount(
        np.concatenate(top).astype(np.int64), minlength=256
    ).astype(np.float64)
    p = hist / hist.sum()
    nz = p[p > 0]
    entropy = float(-(nz * np.log2(nz)).sum())
    return dict(sortedness=float(sortedness), top_bits_entropy=entropy,
                n=n, num_words=codec.num_words)


def recommend_strategy(x, cfg: SortConfig = DEFAULT_CONFIG, *,
                       sample_size: int = 4096) -> str:
    """Pick the local-sort strategy for concrete data ``x`` (module
    docstring has the decision rule and thresholds)."""
    _require_concrete(x)
    sig = probe(
        x, sample_size=sample_size, descending=cfg.descending
    )
    if sig["sortedness"] >= SORTEDNESS_MERGE_THRESHOLD:
        return "merge"
    if (
        sig["num_words"] == 1
        and sig["n"] >= RADIX_MIN_N
        and sig["top_bits_entropy"] >= ENTROPY_RADIX_THRESHOLD_BITS
    ):
        return "radix"
    return "bitonic"


def priors_for(x, cfg: SortConfig = DEFAULT_CONFIG, *,
               sample_size: int = 4096):
    """Distribution priors for the analytic cost model, measured on a
    host-side sample of ``x`` — the bridge between the probe's two
    signals and strategy-dependent cost terms (DESIGN.md §10):
    ``sortedness`` discounts the merge path's compare work,
    ``top_bits_entropy`` scales the radix pass count for skewed digit
    histograms.  Feed the result to ``autotune(..., priors=...)`` or
    ``plan_for(..., priors=...)`` so the analytic pruning ranks
    candidates for THIS data rather than for uniform-random keys.

    Example:
        >>> import numpy as np
        >>> from repro.core import probe
        >>> from repro.core.sort_config import SortConfig
        >>> pri = probe.priors_for(np.arange(4096, dtype=np.int32))
        >>> pri.sortedness
        1.0
    """
    from repro.core.cost_model import Priors

    _require_concrete(x)
    sig = probe(x, sample_size=sample_size, descending=cfg.descending)
    return Priors(
        sortedness=sig["sortedness"],
        top_bits_entropy=sig["top_bits_entropy"],
    )


def probed_config(x, cfg: SortConfig = DEFAULT_CONFIG, *,
                  sample_size: int = 4096) -> SortConfig:
    """``cfg`` with ``strategy`` replaced by the probe's pick — the
    ``plan="default"`` path's data-aware entry (no autotuning run).

    Example:
        >>> import numpy as np
        >>> from repro.core import probe
        >>> from repro.core.sort_config import SortConfig
        >>> x = np.arange(100_000, dtype=np.int32)
        >>> probe.probed_config(x, SortConfig()).strategy
        'merge'
    """
    return dataclasses.replace(
        cfg, strategy=recommend_strategy(x, cfg, sample_size=sample_size)
    )
