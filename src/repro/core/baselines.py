"""Baselines the paper compares against (§5, Figs. 6-7), reproduced in JAX.

1. ``randomized_sample_sort`` — Leischner/Osipov/Sanders (IPDPS'10):
   identical pipeline to Algorithm 1 but splitters come from RANDOM
   samples.  Bucket sizes are then only probabilistically bounded, so a
   static-shape TPU implementation must pick a capacity factor and can
   OVERFLOW (elements dropped -> retry with a larger factor).  We expose
   the overflow count and max bucket fill — the quantities whose
   input-distribution dependence is the paper's core argument (C2).

2. ``merge_sort`` — Thrust-Merge-like (Satish/Harris/Garland IPDPS'09):
   bitonic-sorted tiles + log(m) rounds of pairwise bitonic merges.

3. ``xla_sort`` — XLA's native sort (the "vendor library" reference).

All baselines dispatch on the same ``core/key_codec`` codecs as the
main pipeline: every codec dtype works (64-bit keys become two-word
lexicographic sorts and need x64 mode), and the two cfg-taking entries
honor ``cfg.descending``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import guard
from repro.core.key_codec import codec_for
from repro.core.sort_config import DEFAULT_CONFIG, SortConfig, next_pow2, round_up
from repro.kernels import ops
from repro.kernels.bitonic import as_words, lex_gt

_MAXU = jnp.uint32(0xFFFFFFFF)
_IMAX = jnp.int32(2**31 - 1)


# ----------------------------------------------------------------------
# Randomized sample sort (one bucket round + XLA row sort of buckets)
# ----------------------------------------------------------------------


@functools.partial(
    jax.jit, static_argnames=("cfg", "capacity_factor", "with_stats")
)
def _randomized_canonical(kw, rng_key, cfg: SortConfig,
                          capacity_factor: float, with_stats: bool):
    """One randomized bucket round on canonical key words (tuple, msw
    first), payload = original index.  Returns (words, perm, stats)."""
    nw = len(kw)
    (n,) = kw[0].shape
    t, s = cfg.tile, cfg.s
    lp = round_up(n, t)
    vals = jnp.arange(n, dtype=jnp.int32)
    if lp > n:
        kw = tuple(
            jnp.concatenate([w, jnp.full((lp - n,), _MAXU, jnp.uint32)])
            for w in kw
        )
        vals = jnp.concatenate(
            [vals, lp + jnp.arange(lp - n, dtype=jnp.int32)]
        )
    m = lp // t

    tkw, tv = ops.sort_tiles(
        tuple(w.reshape(m, t) for w in kw), vals.reshape(m, t),
        impl=cfg.impl, interpret=cfg.interpret,
    )

    # RANDOM oversampled splitters (a*s random elements, every a-th of the
    # sorted sample), a la Leischner et al.
    a = 8
    flat_idx = jax.random.randint(rng_key, (a * s,), 0, lp)
    sskw, ssv = ops.sort_tiles(
        tuple(_pad_row(w[flat_idx], _MAXU) for w in kw),
        _pad_row(vals[flat_idx], _IMAX),
        impl=cfg.impl, interpret=cfg.interpret,
    )
    sskw = as_words(sskw)
    sp_idx = jnp.arange(1, s, dtype=jnp.int32) * a
    spkw = tuple(jnp.broadcast_to(w[0, sp_idx], (m, s - 1)) for w in sskw)
    spv = jnp.broadcast_to(ssv[0, sp_idx], (m, s - 1))

    ranks = ops.splitter_ranks(
        tkw, tv, spkw, spv, impl=cfg.impl, interpret=cfg.interpret
    )
    zeros = jnp.zeros((m, 1), jnp.int32)
    starts = jnp.concatenate([zeros, ranks], axis=1)
    counts = (
        jnp.concatenate([ranks, jnp.full((m, 1), t, jnp.int32)], axis=1) - starts
    )
    tile_off = jnp.cumsum(counts, axis=0, dtype=jnp.int32) - counts  # (m, s)
    totals = counts.sum(axis=0, dtype=jnp.int32)  # (s,)

    # NO deterministic bound here -> heuristic static capacity + overflow.
    cap = round_up(int(capacity_factor * lp / s), 128)
    pos = jax.lax.broadcasted_iota(jnp.int32, (m, t), 1)
    ind = jnp.zeros((m, t + 1), jnp.int32)
    ind = ind.at[jax.lax.broadcasted_iota(jnp.int32, ranks.shape, 0), ranks].add(1)
    bucket_id = jnp.cumsum(ind, axis=1, dtype=jnp.int32)[:, :t]
    p_rel = pos - jnp.take_along_axis(starts, bucket_id, axis=1)
    within = jnp.take_along_axis(tile_off, bucket_id, axis=1) + p_rel
    dest = bucket_id * cap + within
    overflow = jnp.sum(within >= cap)
    dest = jnp.where(within < cap, dest, s * cap).reshape(-1)

    bkw = tuple(
        jnp.full((s * cap,), _MAXU, jnp.uint32)
        .at[dest].set(w.reshape(-1), mode="drop")
        for w in tkw
    )
    bv = jnp.full((s * cap,), _IMAX, jnp.int32)
    bv = bv.at[dest].set(tv.reshape(-1), mode="drop")

    # bucket sort via XLA row sort (stand-in for the recursive step 9)
    out = jax.lax.sort(
        tuple(w.reshape(s, cap) for w in bkw) + (bv.reshape(s, cap),),
        dimension=-1, num_keys=nw + 1,
    )
    skw2, sv2 = out[:-1], out[-1]

    # compact buckets back to dense
    boff = jnp.cumsum(totals, dtype=jnp.int32) - totals
    p = jax.lax.broadcasted_iota(jnp.int32, (s, cap), 1)
    valid = p < totals[:, None]
    dflat = jnp.where(valid, boff[:, None] + p, lp).reshape(-1)
    okw = tuple(
        jnp.full((lp,), _MAXU, jnp.uint32)
        .at[dflat].set(w.reshape(-1), mode="drop")
        for w in skw2
    )
    ovv = jnp.full((lp,), _IMAX, jnp.int32)
    ovv = ovv.at[dflat].set(sv2.reshape(-1), mode="drop")
    stats = (jnp.max(totals), overflow) if with_stats else (None, None)
    return tuple(w[:n] for w in okw), ovv[:n], stats


def _pad_row(x, fill):
    n = x.shape[0]
    lp = next_pow2(n)
    if lp > n:
        x = jnp.concatenate([x, jnp.full((lp - n,), fill, x.dtype)])
    return x[None]


def randomized_sample_sort(
    x: jax.Array,
    rng_key,
    cfg: SortConfig = DEFAULT_CONFIG,
    capacity_factor: float = 4.0,
    with_stats: bool = False,
    max_attempts: int = 4,
):
    """Randomized sample sort baseline, with the retry loop a real
    deployment of Leischner et al. needs: bucket sizes are only
    probabilistically bounded, so on overflow (elements dropped, result
    invalid) the sort re-runs with the capacity factor DOUBLED and the
    splitter sample re-drawn (``jax.random.fold_in(rng_key, attempt)``),
    up to ``max_attempts`` times.  Each retry is recorded in
    ``guard.degradation_log()``; exhausting the budget raises a
    structured :class:`repro.core.guard.SortRuntimeError`.  The retry
    loop itself is part of the paper's argument (C2): the deterministic
    algorithm's static capacity bound makes it unnecessary.

    Args:
        x: 1-D array of any codec dtype (``cfg.descending`` honored).
        rng_key: jax PRNG key for the random splitter sample.
        capacity_factor: static bucket capacity = factor * n/s
            (doubles on each retry).
        with_stats: also return (max_bucket_fill, overflow_count) of
            the attempt that produced the returned arrays.
        max_attempts: retry budget.  ``1`` = raw single-shot mode: the
            possibly-overflowed result and its stats are returned as-is
            (never raises) — the observational mode the
            distribution-robustness benchmark uses to MEASURE overflow.
    Returns:
        (sorted, perm[, stats]).
    Raises:
        repro.core.guard.SortRuntimeError: overflow persisted through
            ``max_attempts`` attempts (only when ``max_attempts > 1``).
    """
    assert max_attempts >= 1
    codec = codec_for(x.dtype, cfg.descending)
    kw = codec.encode(x)
    site = f"baselines.randomized_sample_sort(n={x.shape[0]})"
    factor = capacity_factor
    for attempt in range(max_attempts):
        key = rng_key if attempt == 0 else jax.random.fold_in(rng_key, attempt)
        skw, sv, stats = _randomized_canonical(kw, key, cfg, factor, True)
        ovf = int(stats[1])
        if ovf == 0 or max_attempts == 1:
            out = codec.decode(skw)
            if with_stats:
                return out, sv, stats
            return out, sv
        if attempt + 1 < max_attempts:
            guard.record_degradation(
                site, "retry",
                f"capacity_factor={factor:g}",
                f"capacity_factor={factor * 2:g}, splitter sample re-drawn",
                f"{ovf} element(s) overflowed the static buckets",
            )
            factor *= 2.0
    raise guard.SortRuntimeError(
        site, "bucket fill <= static capacity",
        f"overflow persisted after {max_attempts} attempts "
        f"(final capacity_factor={factor:g}, overflow={ovf}); the "
        f"deterministic sort (core/bucket_sort.py) has no such failure mode",
    )


# ----------------------------------------------------------------------
# Thrust-Merge-like: bitonic tile sort + log(m) pairwise merge rounds
# ----------------------------------------------------------------------


def _bitonic_merge_rows(parts):
    """Merge rows of (r, 2L) parts where [:, :L] ascends and [:, L:]
    descends, jointly over (key words + payload)."""
    c = parts[0].shape[-1]
    d = c // 2
    while d >= 1:
        parts = _merge_pass(parts, d)
        d //= 2
    return parts


def _merge_pass(parts, d):
    lead = parts[0].shape[:-1]
    c = parts[0].shape[-1]
    r3 = [p.reshape(lead + (c // (2 * d), 2, d)) for p in parts]
    los = [p[..., 0, :] for p in r3]
    his = [p[..., 1, :] for p in r3]
    swap = lex_gt(los, his)
    return tuple(
        jnp.stack(
            (jnp.where(swap, hi, lo), jnp.where(swap, lo, hi)), axis=-2
        ).reshape(lead + (c,))
        for lo, hi in zip(los, his)
    )


@functools.partial(jax.jit, static_argnames=("cfg",))
def _merge_canonical(kw, cfg: SortConfig):
    (n,) = kw[0].shape
    t = cfg.tile
    lp = max(round_up(n, t), t)
    vals = jnp.arange(n, dtype=jnp.int32)
    if lp > n:
        kw = tuple(
            jnp.concatenate([w, jnp.full((lp - n,), _MAXU, jnp.uint32)])
            for w in kw
        )
        vals = jnp.concatenate([vals, lp + jnp.arange(lp - n, dtype=jnp.int32)])
    m = lp // t
    tkw, tv = ops.sort_tiles(
        tuple(w.reshape(m, t) for w in kw), vals.reshape(m, t),
        impl=cfg.impl, interpret=cfg.interpret,
    )
    # pad row count to a power of two with all-MAX rows
    mp = next_pow2(m)
    if mp > m:
        tkw = tuple(
            jnp.concatenate(
                [w, jnp.full((mp - m, t), _MAXU, jnp.uint32)], axis=0
            )
            for w in tkw
        )
        tv = jnp.concatenate([tv, jnp.full((mp - m, t), _IMAX, jnp.int32)], axis=0)
    parts = tkw + (tv,)
    while parts[0].shape[0] > 1:
        # bitonic rows: even rows ascend, odd rows reversed (descend)
        cat = tuple(
            jnp.concatenate([p[0::2], p[1::2][:, ::-1]], axis=1)
            for p in parts
        )
        parts = _bitonic_merge_rows(cat)
    return tuple(p[0, :n] for p in parts[:-1]), parts[-1][0, :n]


def merge_sort(x: jax.Array, cfg: SortConfig = DEFAULT_CONFIG):
    """Thrust-Merge-like baseline: tile sort + pairwise bitonic merging.

    Args:
        x: 1-D array of any codec dtype (``cfg.descending`` honored).
    Returns:
        (sorted, perm) — stable, like the main pipeline.
    """
    codec = codec_for(x.dtype, cfg.descending)
    skw, sv = _merge_canonical(codec.encode(x), cfg)
    return codec.decode(skw), sv


# ----------------------------------------------------------------------
# XLA native sort
# ----------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("descending",))
def xla_sort(x: jax.Array, descending: bool = False):
    """XLA's built-in sort (reference oracle + perf baseline).

    Args:
        x: 1-D array of any codec dtype.
        descending: stable descending order (codec complement).
    Returns:
        (sorted, perm) with perm the stable argsort.
    """
    codec = codec_for(x.dtype, descending)
    kw = codec.encode(x)
    idx = jnp.arange(x.shape[0], dtype=jnp.int32)
    out = jax.lax.sort((*kw, idx), dimension=0, num_keys=len(kw) + 1)
    return codec.decode(tuple(out[:-1])), out[-1]


@functools.partial(jax.jit, static_argnames=("descending",))
def xla_sort_batched(x: jax.Array, descending: bool = False):
    """XLA's built-in row-wise sort of (B, L): the reference oracle and
    perf baseline for ``sort_batched`` (stable via index tiebreak).

    Args/Returns: as :func:`xla_sort`, per row.
    """
    b, length = x.shape
    codec = codec_for(x.dtype, descending)
    kw = codec.encode(x)
    idx = jnp.broadcast_to(
        jnp.arange(length, dtype=jnp.int32)[None, :], (b, length)
    )
    out = jax.lax.sort((*kw, idx), dimension=1, num_keys=len(kw) + 1)
    return codec.decode(tuple(out[:-1])), out[-1]
