"""Baselines the paper compares against (§5, Figs. 6-7), reproduced in JAX.

1. ``randomized_sample_sort`` — Leischner/Osipov/Sanders (IPDPS'10):
   identical pipeline to Algorithm 1 but splitters come from RANDOM
   samples.  Bucket sizes are then only probabilistically bounded, so a
   static-shape TPU implementation must pick a capacity factor and can
   OVERFLOW (elements dropped -> retry with a larger factor).  We expose
   the overflow count and max bucket fill — the quantities whose
   input-distribution dependence is the paper's core argument (C2).

2. ``merge_sort`` — Thrust-Merge-like (Satish/Harris/Garland IPDPS'09):
   bitonic-sorted tiles + log(m) rounds of pairwise bitonic merges.

3. ``xla_sort`` — XLA's native sort (the "vendor library" reference).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.sort_config import DEFAULT_CONFIG, SortConfig, next_pow2, round_up
from repro.kernels import ops
from repro.kernels.bitonic import bitonic_network_rows

_MAXU = jnp.uint32(0xFFFFFFFF)
_IMAX = jnp.int32(2**31 - 1)


# ----------------------------------------------------------------------
# Randomized sample sort (one bucket round + XLA row sort of buckets)
# ----------------------------------------------------------------------


@functools.partial(
    jax.jit, static_argnames=("cfg", "capacity_factor", "with_stats")
)
def _randomized_canonical(u, rng_key, cfg: SortConfig, capacity_factor: float,
                          with_stats: bool):
    (n,) = u.shape
    t, s = cfg.tile, cfg.s
    lp = round_up(n, t)
    vals = jnp.arange(n, dtype=jnp.int32)
    if lp > n:
        u = jnp.concatenate([u, jnp.full((lp - n,), _MAXU, jnp.uint32)])
        vals = jnp.concatenate(
            [vals, lp + jnp.arange(lp - n, dtype=jnp.int32)]
        )
    m = lp // t

    tk, tv = ops.sort_tiles(
        u.reshape(m, t), vals.reshape(m, t), impl=cfg.impl, interpret=cfg.interpret
    )

    # RANDOM oversampled splitters (a*s random elements, every a-th of the
    # sorted sample), a la Leischner et al.
    a = 8
    flat_idx = jax.random.randint(rng_key, (a * s,), 0, lp)
    sk = u[flat_idx]
    sv = vals[flat_idx]
    ssk, ssv = ops.sort_tiles(
        _pad_row(sk, _MAXU), _pad_row(sv, _IMAX),
        impl=cfg.impl, interpret=cfg.interpret,
    )
    sp_idx = jnp.arange(1, s, dtype=jnp.int32) * a
    spk = jnp.broadcast_to(ssk[0, sp_idx], (m, s - 1))
    spv = jnp.broadcast_to(ssv[0, sp_idx], (m, s - 1))

    ranks = ops.splitter_ranks(
        tk, tv, spk, spv, impl=cfg.impl, interpret=cfg.interpret
    )
    zeros = jnp.zeros((m, 1), jnp.int32)
    starts = jnp.concatenate([zeros, ranks], axis=1)
    counts = (
        jnp.concatenate([ranks, jnp.full((m, 1), t, jnp.int32)], axis=1) - starts
    )
    tile_off = jnp.cumsum(counts, axis=0) - counts  # (m, s)
    totals = counts.sum(axis=0)  # (s,)

    # NO deterministic bound here -> heuristic static capacity + overflow.
    cap = round_up(int(capacity_factor * lp / s), 128)
    pos = jax.lax.broadcasted_iota(jnp.int32, (m, t), 1)
    ind = jnp.zeros((m, t + 1), jnp.int32)
    ind = ind.at[jax.lax.broadcasted_iota(jnp.int32, ranks.shape, 0), ranks].add(1)
    bucket_id = jnp.cumsum(ind, axis=1)[:, :t]
    p_rel = pos - jnp.take_along_axis(starts, bucket_id, axis=1)
    within = jnp.take_along_axis(tile_off, bucket_id, axis=1) + p_rel
    dest = bucket_id * cap + within
    overflow = jnp.sum(within >= cap)
    dest = jnp.where(within < cap, dest, s * cap)

    bk = jnp.full((s * cap,), _MAXU, jnp.uint32)
    bv = jnp.full((s * cap,), _IMAX, jnp.int32)
    bk = bk.at[dest.reshape(-1)].set(tk.reshape(-1), mode="drop")
    bv = bv.at[dest.reshape(-1)].set(tv.reshape(-1), mode="drop")

    # bucket sort via XLA row sort (stand-in for the recursive step 9)
    sk2, sv2 = jax.lax.sort(
        (bk.reshape(s, cap), bv.reshape(s, cap)), dimension=-1, num_keys=2
    )

    # compact buckets back to dense
    boff = jnp.cumsum(totals) - totals
    p = jax.lax.broadcasted_iota(jnp.int32, (s, cap), 1)
    valid = p < totals[:, None]
    dflat = jnp.where(valid, boff[:, None] + p, lp)
    okk = jnp.full((lp,), _MAXU, jnp.uint32)
    ovv = jnp.full((lp,), _IMAX, jnp.int32)
    okk = okk.at[dflat.reshape(-1)].set(sk2.reshape(-1), mode="drop")
    ovv = ovv.at[dflat.reshape(-1)].set(sv2.reshape(-1), mode="drop")
    stats = (jnp.max(totals), overflow) if with_stats else (None, None)
    return okk[:n], ovv[:n], stats


def _pad_row(x, fill):
    n = x.shape[0]
    lp = next_pow2(n)
    if lp > n:
        x = jnp.concatenate([x, jnp.full((lp - n,), fill, x.dtype)])
    return x[None]


def randomized_sample_sort(
    x: jax.Array,
    rng_key,
    cfg: SortConfig = DEFAULT_CONFIG,
    capacity_factor: float = 4.0,
    with_stats: bool = False,
):
    """Randomized sample sort baseline.  Returns (sorted, perm[, stats]).

    stats = (max_bucket_fill, overflow_count): overflow > 0 means dropped
    elements (result invalid — caller must retry with a larger factor).
    This data-dependent failure mode is precisely what the deterministic
    algorithm eliminates.
    """
    u = ops.to_sortable(x)
    sk, sv, stats = _randomized_canonical(
        u, rng_key, cfg, capacity_factor, with_stats
    )
    out = ops.from_sortable(sk, x.dtype)
    if with_stats:
        return out, sv, stats
    return out, sv


# ----------------------------------------------------------------------
# Thrust-Merge-like: bitonic tile sort + log(m) pairwise merge rounds
# ----------------------------------------------------------------------


def _bitonic_merge_rows(keys, vals):
    """Merge rows of (r, 2L) where [:, :L] ascends and [:, L:] descends."""
    c = keys.shape[-1]
    d = c // 2
    while d >= 1:
        keys, vals = _merge_pass(keys, vals, d)
        d //= 2
    return keys, vals


def _merge_pass(keys, vals, d):
    lead = keys.shape[:-1]
    c = keys.shape[-1]
    k3 = keys.reshape(lead + (c // (2 * d), 2, d))
    v3 = vals.reshape(lead + (c // (2 * d), 2, d))
    klo, khi = k3[..., 0, :], k3[..., 1, :]
    vlo, vhi = v3[..., 0, :], v3[..., 1, :]
    swap = (klo > khi) | ((klo == khi) & (vlo > vhi))
    nk = jnp.stack(
        (jnp.where(swap, khi, klo), jnp.where(swap, klo, khi)), axis=-2
    ).reshape(lead + (c,))
    nv = jnp.stack(
        (jnp.where(swap, vhi, vlo), jnp.where(swap, vlo, vhi)), axis=-2
    ).reshape(lead + (c,))
    return nk, nv


@functools.partial(jax.jit, static_argnames=("cfg",))
def _merge_canonical(u, cfg: SortConfig):
    (n,) = u.shape
    t = cfg.tile
    lp = max(round_up(n, t), t)
    vals = jnp.arange(n, dtype=jnp.int32)
    if lp > n:
        u = jnp.concatenate([u, jnp.full((lp - n,), _MAXU, jnp.uint32)])
        vals = jnp.concatenate([vals, lp + jnp.arange(lp - n, dtype=jnp.int32)])
    m = lp // t
    tk, tv = ops.sort_tiles(
        u.reshape(m, t), vals.reshape(m, t), impl=cfg.impl, interpret=cfg.interpret
    )
    # pad row count to a power of two with all-MAX rows
    mp = next_pow2(m)
    if mp > m:
        tk = jnp.concatenate(
            [tk, jnp.full((mp - m, t), _MAXU, jnp.uint32)], axis=0
        )
        tv = jnp.concatenate([tv, jnp.full((mp - m, t), _IMAX, jnp.int32)], axis=0)
    while tk.shape[0] > 1:
        r, length = tk.shape
        a_k, b_k = tk[0::2], tk[1::2]
        a_v, b_v = tv[0::2], tv[1::2]
        cat_k = jnp.concatenate([a_k, b_k[:, ::-1]], axis=1)  # bitonic rows
        cat_v = jnp.concatenate([a_v, b_v[:, ::-1]], axis=1)
        tk, tv = _bitonic_merge_rows(cat_k, cat_v)
    return tk[0, :n], tv[0, :n]


def merge_sort(x: jax.Array, cfg: SortConfig = DEFAULT_CONFIG):
    """Thrust-Merge-like baseline: tile sort + pairwise bitonic merging."""
    u = ops.to_sortable(x)
    sk, sv = _merge_canonical(u, cfg)
    return ops.from_sortable(sk, x.dtype), sv


# ----------------------------------------------------------------------
# XLA native sort
# ----------------------------------------------------------------------


@jax.jit
def xla_sort(x: jax.Array):
    """XLA's built-in sort (reference oracle + perf baseline)."""
    idx = jnp.arange(x.shape[0], dtype=jnp.int32)
    u = ops.to_sortable(x)
    sk, sv = jax.lax.sort((u, idx), dimension=0, num_keys=2)
    return ops.from_sortable(sk, x.dtype), sv


@jax.jit
def xla_sort_batched(x: jax.Array):
    """XLA's built-in row-wise sort of (B, L): the reference oracle and
    perf baseline for ``sort_batched`` (stable via index tiebreak)."""
    b, length = x.shape
    idx = jnp.broadcast_to(
        jnp.arange(length, dtype=jnp.int32)[None, :], (b, length)
    )
    u = ops.to_sortable(x)
    sk, sv = jax.lax.sort((u, idx), dimension=1, num_keys=2)
    return ops.from_sortable(sk, x.dtype), sv
