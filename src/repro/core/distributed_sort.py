"""Distributed deterministic sample sort across a TPU mesh (shard_map).

The paper is single-GPU; this module scales Algorithm 1 to chips/pods.
It is the cluster-level analogue of the paper's bucket phase, with one
extra "deal" round that restores the *guaranteed-capacity* property at
per-device-pair granularity — the property that makes the exchange a
single STATIC ``lax.all_to_all`` (XLA requires static shapes; a
randomized splitter choice admits no such bound — DESIGN.md §2, §9).

Per-shard pipeline (axis size D, local length n_loc, oversample c):

  1. local sort            (Algorithm 1 on the shard)
  2. DEAL: element p of the local sorted run goes to device (p mod D)
     via a static all_to_all transpose.  Afterwards every device holds a
     stride-D regular sample of *every* device's sorted data.
  3. local sort of the dealt data
  4. sampling: s_loc = c*D equidistant local samples, all_gather,
     replicated sort, D-1 equidistant global splitters  (steps 3-5)
  5. splitter ranks -> per-target chunk sizes            (steps 6-7)
  6. one static all_to_all of (D, C_pair) buckets        (step 8)
  7. local sort of received buckets                      (step 9)

Capacity guarantee: global bucket t holds B_t <= n_loc * (1 + 1/c)
elements (regular sampling, unique (key, payload) pairs).  The deal
makes every device hold (b_it/D ± 1) of source i's bucket-t elements, so

    chunk(j -> t) <= B_t/D + D  <=  n_loc*(1+1/c)/D + D  =: C_pair  (static!)

Overflow is therefore impossible; tests assert max fill <= C_pair.
The result is returned padded-ragged: (out_cap,) keys/payloads per
shard plus a valid-count — the natural output of a sample sort (global
order = concatenation of valid prefixes in device order).

PLAN-AWARE (DESIGN.md §9): the ENTIRE distributed schedule — mesh axis
and D, n_pad, oversample, deal geometry, the c_pair/out_cap
capacities, and the four per-phase local-sort :class:`SortPlan`s — is
a frozen :class:`repro.core.plan.ShardPlan` computed once by
``build_shard_plan`` (or tuned by ``autotune.shard_plan_for``).
:func:`sorted_shard` is a pure executor that derives nothing, and the
jit'd entry takes ``(mesh, plan)`` as STATIC arguments: equal
``(shape, mesh, dtype, plan)`` signatures share one compiled
executable (``trace_count`` exposes the counter; tests assert
trace-once / zero-retrace discipline exactly as the single-device path
does).  The per-phase plans inherit the strategy dispatch (DESIGN.md
§8), so shards can radix- or merge-sort their local runs.

Keys dispatch on the ``core/key_codec`` codecs like the single-device
pipeline: ``make_sharded_sort`` accepts any codec dtype (64-bit keys
travel as two uint32 words per element through every collective; x64
mode required) and honors ``cfg.descending``.  ``sorted_shard`` itself
operates on canonical words — a bare uint32 array or a tuple of word
arrays, returned in the same structure.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.core import faults, guard
from repro.core.bucket_sort import _run_node
from repro.core.key_codec import codec_for
from repro.core.plan import ShardPlan, SortPlan, build_shard_plan, shard_geometry
from repro.core.sort_config import DEFAULT_CONFIG, SortConfig
from repro.kernels import ops
from repro.kernels.bitonic import as_words, like_words

_MAXU = jnp.uint32(0xFFFFFFFF)

# Python-side retrace counter for the jit'd distributed entry
# (increments once per TRACE, not per call) — the distributed analogue
# of ``bucket_sort.trace_count``; tests assert same-(mesh, n, dtype,
# plan) => one trace and plan-cache hit => zero retraces with it.
_TRACE_COUNT = 0


def trace_count() -> int:
    """Number of times the distributed entry has been TRACED in this
    process (a retrace/compile-discipline counter for tests)."""
    return _TRACE_COUNT


@dataclasses.dataclass(frozen=True)
class DistSortSpec:
    """Static geometry of a distributed sort (all trace-time ints).

    Retained as the minimal arithmetic view of the schedule (the
    hypothesis property tests exercise it directly); every derived
    quantity delegates to :func:`repro.core.plan.shard_geometry`, the
    single source of truth the :class:`~repro.core.plan.ShardPlan`
    builder also reads.

    Attributes:
        axis: mesh axis name (or tuple of names) the sort spans.
        d: devices along the sort axis.
        n_local: local shard length (pre-padding).
        oversample: regular-sampling oversample factor c (bound above).
        pair_align: lane alignment of the per-pair exchange capacity.
    """

    axis: str | tuple[str, ...]
    d: int  # devices along the sort axis
    n_local: int  # local shard length (pre-padding)
    oversample: int = 8
    pair_align: int = 8

    @property
    def axis_tuple(self):
        return (self.axis,) if isinstance(self.axis, str) else tuple(self.axis)

    @property
    def _geometry(self):
        return shard_geometry(
            self.n_local, self.d, self.oversample, self.pair_align
        )

    @property
    def s_loc(self) -> int:
        return self._geometry.s_loc

    @property
    def n_pad(self) -> int:
        # Padded so the deal (multiple of d) and the equidistant sampling
        # (multiple of s_loc = oversample*d) are both exact — exact spacing
        # is what the capacity-bound proof relies on.
        return self._geometry.n_pad

    @property
    def b_t(self) -> int:
        """Max global bucket size: B_t <= n_pad * (1 + 1/oversample)."""
        return self._geometry.b_t

    @property
    def c_pair(self) -> int:
        """Static per-pair all_to_all capacity: B_t/D + D (deal bound)."""
        return self._geometry.c_pair

    @property
    def out_cap(self) -> int:
        """Static per-shard output capacity >= any bucket total B_t."""
        return self._geometry.out_cap


def _local_sort(kw, v, sub: SortPlan, pad_base):
    """Pure plan-driven local sort: hand one per-phase ``SortPlan`` off
    the :class:`ShardPlan` to the plan executor — nothing is derived
    here (shapes must match the sub-plan exactly; ``_run_node``
    asserts it)."""
    skw, sv, _ = _run_node(
        tuple(w[None, :] for w in kw), v[None, :], sub.root, sub.impl,
        sub.interpret, pad_base, None,
    )
    return tuple(w[0] for w in skw), sv[0]


def _deal_all_to_all(x, ax, d, n_pad):
    """Deal: position p -> device p mod D (static transpose all_to_all)."""
    x = jnp.swapaxes(x.reshape(n_pad // d, d), 0, 1)  # (D, n_pad/D) strided
    return jax.lax.all_to_all(x, ax, split_axis=0, concat_axis=0, tiled=False)


def sorted_shard(keys_local, vals_local: jax.Array, plan: ShardPlan):
    """Distributed sort body — call INSIDE shard_map over ``plan.axis``.

    A pure EXECUTOR of the :class:`~repro.core.plan.ShardPlan`: every
    static quantity (D, n_pad, s_loc, c_pair, out_cap, the four
    per-phase local-sort schedules, impl/interpret) is read off the
    plan; nothing is recomputed here (DESIGN.md §9).

    Args:
        keys_local: (n_local,) canonical uint32 key words — bare array
            or tuple of word arrays (msw first, see ``core/key_codec``)
            with ``plan.num_words`` words.
        vals_local: (n_local,) int32 payloads, globally unique (use
            global indices).
        plan: the static distributed schedule
            (:func:`repro.core.plan.build_shard_plan`).
    Returns:
        (keys (out_cap,) in the input structure, vals (out_cap,),
        count (), max_within ()) — valid prefix of each shard; shards
        concatenated in device order form the globally sorted sequence.
    """
    kw = as_words(keys_local)
    ax = plan.axis if len(plan.axis) > 1 else plan.axis[0]
    d, n_pad, s_loc, c_pair = plan.d, plan.n_pad, plan.s_loc, plan.c_pair
    n_glob = plan.n_glob
    pad_base = n_glob  # payloads are global indices < n_glob

    me = jax.lax.axis_index(ax)
    # Pad shard to a multiple of D with unique (all-ones, >= n_glob) pads.
    n0 = kw[0].shape[0]
    pad_n = n_pad - n0
    if pad_n:
        pk = jnp.full((pad_n,), _MAXU, jnp.uint32)
        pv = n_glob + me * pad_n + jnp.arange(pad_n, dtype=jnp.int32)
        kw = tuple(jnp.concatenate([w, pk]) for w in kw)
        vals_local = jnp.concatenate([vals_local, pv])
    v = vals_local
    pad_base += d * n_pad

    # 1. local sort
    kw, v = _local_sort(kw, v, plan.run_plan, pad_base)
    pad_base += 4 * n_glob  # disjoint pad range headroom per phase

    # 2. deal: one static all_to_all transpose per word + payload
    kw = tuple(_deal_all_to_all(w, ax, d, n_pad).reshape(n_pad) for w in kw)
    v = _deal_all_to_all(v, ax, d, n_pad).reshape(n_pad)

    # 3. local sort of dealt data
    kw, v = _local_sort(kw, v, plan.dealt_plan, pad_base)
    pad_base += 4 * n_glob

    # 4. sampling -> replicated splitters (steps 3-5 of Algorithm 1)
    samp_idx = (jnp.arange(1, s_loc + 1, dtype=jnp.int32) * (n_pad // s_loc)) - 1
    skw_all = tuple(
        jax.lax.all_gather(w[samp_idx], ax).reshape(d * s_loc) for w in kw
    )
    sv_all = jax.lax.all_gather(v[samp_idx], ax).reshape(d * s_loc)
    sskw, ssv = _local_sort(skw_all, sv_all, plan.sample_plan, pad_base)
    pad_base += 4 * d * s_loc
    sp_idx = (jnp.arange(1, d, dtype=jnp.int32) * (d * s_loc)) // d
    spkw = tuple(w[sp_idx] for w in sskw)  # (D-1,) identical on every device
    spv = ssv[sp_idx]

    # 5. splitter ranks -> chunk geometry (steps 6-7)
    ranks = ops.splitter_ranks(
        tuple(w[None, :] for w in kw), v[None, :],
        tuple(w[None, :] for w in spkw), spv[None, :],
        impl=plan.impl, interpret=plan.interpret,
    )[0]  # (D-1,) in [0, n_pad]
    starts = jnp.concatenate([jnp.zeros((1,), jnp.int32), ranks])
    ends = jnp.concatenate([ranks, jnp.full((1,), n_pad, jnp.int32)])
    counts = ends - starts  # (D,) elements per target device

    # 6. scatter into the padded (D, C_pair) buffer, one static all_to_all
    pos = jnp.arange(n_pad, dtype=jnp.int32)
    ind = jnp.zeros((n_pad + 1,), jnp.int32).at[ranks].add(1)
    chunk_id = jnp.cumsum(ind, dtype=jnp.int32)[:n_pad]
    within = pos - jnp.take(starts, chunk_id)
    max_within = jnp.max(within)  # bound check: < C_pair (tested)
    dest = chunk_id * c_pair + within
    dest = jnp.where(within < c_pair, dest, d * c_pair)
    bkw = tuple(
        jnp.full((d * c_pair,), _MAXU, jnp.uint32).at[dest].set(w, mode="drop")
        for w in kw
    )
    bv = (
        jnp.int32(pad_base) + jnp.arange(d * c_pair, dtype=jnp.int32)
    ).at[dest].set(v, mode="drop")
    pad_base += d * d * c_pair

    faults.check("collective.exchange")  # trace-time chaos site (§11)
    bkw = tuple(
        jax.lax.all_to_all(
            w.reshape(d, c_pair), ax, split_axis=0, concat_axis=0, tiled=False
        )
        for w in bkw
    )
    bv = jax.lax.all_to_all(
        bv.reshape(d, c_pair), ax, split_axis=0, concat_axis=0, tiled=False
    )
    recv_counts = jax.lax.all_to_all(
        counts.reshape(d, 1), ax, split_axis=0, concat_axis=0, tiled=False
    ).reshape(d)

    # 7. local sort of the received buckets (step 9); reals sort before pads
    fkw, fv = _local_sort(
        tuple(w.reshape(d * c_pair) for w in bkw), bv.reshape(d * c_pair),
        plan.bucket_plan, pad_base,
    )
    out_cap = plan.out_cap
    count = jnp.sum(recv_counts, dtype=jnp.int32)
    # Padded shard elements (payload in [n_glob, n_glob + d*n_pad)) are real
    # inputs' pads: they sort after all true elements; exclude them.
    count = count - jnp.sum(
        (fv[:out_cap] >= n_glob) & (fv[:out_cap] < n_glob + d * n_pad),
        dtype=jnp.int32,
    )
    return (
        like_words(tuple(w[:out_cap] for w in fkw), keys_local),
        fv[:out_cap],
        count,
        max_within,
    )


@functools.partial(jax.jit, static_argnames=("mesh", "plan"))
def _sharded_argsort(keys, mesh, plan: ShardPlan):
    """The jit'd distributed entry.  ``mesh`` and ``plan`` are STATIC
    arguments: two ``make_sharded_sort`` calls with equal
    ``(shape, mesh, dtype, plan)`` signatures hit one compiled
    executable (trace-once / zero-retrace, tested)."""
    global _TRACE_COUNT
    _TRACE_COUNT += 1  # python side effect: runs once per TRACE
    codec = codec_for(plan.dtype_name, plan.descending)
    axt = plan.axis
    n_loc = plan.n_local

    def body(keys_local):
        me = jax.lax.axis_index(axt if len(axt) > 1 else axt[0])
        kw = codec.encode(keys_local)
        gid = me * n_loc + jnp.arange(n_loc, dtype=jnp.int32)
        fkw, fv, count, max_within = sorted_shard(kw, gid, plan)
        # Stack words into one (nw, out_cap) array so the shard_map
        # out_specs stay structure-independent of the codec word count.
        return (
            jnp.stack(as_words(fkw))[None],
            fv[None],
            count[None],
            max_within[None],
        )

    pspec = P(axt)
    fkw, fv, counts, mw = shard_map(
        body,
        mesh=mesh,
        in_specs=(pspec,),
        out_specs=(P(axt, None, None), P(axt, None), pspec, pspec),
    )(keys)
    # fkw: (D, nw, out_cap) -> per-word (D*out_cap,) flats -> decode
    words = tuple(fkw[:, i, :].reshape(-1) for i in range(codec.num_words))
    return codec.decode(words), fv.reshape(-1), counts, mw


def _degraded_host_sort(keys, plan: ShardPlan):
    """Last link of the distributed degradation chain (DESIGN.md §11):
    gather the whole array to the host, sort it on one device with a
    single stable ``lax.sort`` over the canonical words + global-index
    payload, and re-emit the distributed output contract — per-shard
    ``out_cap`` chunks whose valid prefixes (``counts[i] == n_local``)
    concatenate to the globally sorted sequence.

    Deterministic and bitwise-equal to the mesh path on the valid
    prefixes; slower (no parallelism) and returns unsharded arrays.
    ``max_within`` is reported as 0 (no exchange ran)."""
    import numpy as np

    codec = codec_for(plan.dtype_name, plan.descending)
    n = plan.d * plan.n_local
    x = jnp.asarray(np.asarray(jax.device_get(keys)))
    kw = as_words(codec.encode(x))
    gid = jnp.arange(n, dtype=jnp.int32)
    out = jax.lax.sort(tuple(kw) + (gid,), num_keys=len(kw) + 1)
    sk = codec.decode(tuple(out[:-1]))
    sv = np.asarray(out[-1])
    skn = np.asarray(sk)
    d, oc, n_loc = plan.d, plan.out_cap, plan.n_local
    out_k = np.zeros((d, oc), dtype=skn.dtype)
    out_v = np.full((d, oc), np.int32(2**31 - 1), np.int32)
    for i in range(d):
        chunk = skn[i * n_loc:(i + 1) * n_loc]
        out_k[i, :n_loc] = chunk
        if n_loc and oc > n_loc:
            out_k[i, n_loc:] = chunk[-1]  # inert pad content
        out_v[i, :n_loc] = sv[i * n_loc:(i + 1) * n_loc]
    counts = np.full((d,), n_loc, np.int32)
    mw = np.zeros((d,), np.int32)
    return (
        jnp.asarray(out_k.reshape(-1)),
        jnp.asarray(out_v.reshape(-1)),
        jnp.asarray(counts),
        jnp.asarray(mw),
    )


def _axis_degree(mesh, axis) -> tuple[tuple[str, ...], int]:
    axt = (axis,) if isinstance(axis, str) else tuple(axis)
    d = 1
    for a in axt:
        d *= mesh.shape[a]
    return axt, d


def _resolve_shard_plan(
    mesh, axt, d, n_global: int, dtype, cfg: SortConfig,
    oversample: int, pair_align: int,
) -> ShardPlan:
    """Obtain the distributed plan per ``cfg.plan`` ("default" builds it
    from the config; "autotune" goes through the persistent shard-plan
    cache, tuning on the first miss; any other string loads a shard-plan
    file saved by ``autotune.save_shard_plan``)."""
    if cfg.plan == "default":
        return build_shard_plan(
            axt, d, n_global // d, dtype, cfg,
            oversample=oversample, pair_align=pair_align,
        )
    from repro.core import autotune  # deferred: autotune imports core.plan

    if cfg.plan == "autotune":
        return autotune.shard_plan_for(
            mesh, axt, n_global, dtype, cfg,
            oversample=oversample, pair_align=pair_align,
        )
    return autotune.load_shard_plan(
        cfg.plan, axis=axt, d=d, n_local=n_global // d, dtype=dtype, cfg=cfg,
    )


def make_sharded_sort(
    mesh, axis, n_global: int, cfg: SortConfig = DEFAULT_CONFIG,
    oversample: int = 8, *, dtype=jnp.int32, pair_align: int = 8,
):
    """Build a jit'd distributed argsort over ``axis`` of ``mesh``.

    Args:
        mesh: jax device mesh.
        axis: mesh axis name (or tuple) to sort across; D = its size.
        n_global: total key count (must divide by D).
        cfg: pipeline knobs (``descending`` supported; ``cfg.plan``
            selects the schedule: "default" builds it from this config,
            "autotune" uses the measured-best distributed plan from the
            persistent cache, any other string loads a shard-plan
            file).
        oversample: regular-sampling oversample factor (power of two).
        dtype: key dtype the returned fn accepts (any codec dtype —
            64-bit needs x64 mode).  Part of the plan signature.
        pair_align: lane alignment of the per-pair exchange capacity.
    Returns:
        (fn, plan) where fn: (keys (n_global,) sharded over axis) ->
          (sorted_keys (D*out_cap,), payload_idx (D*out_cap,),
           counts (D,), max_within (D,))
        and the valid prefix of each shard (counts[i] elements)
        concatenated in shard order is the globally sorted sequence;
        payloads are original global indices (an argsort).  ``plan`` is
        the frozen :class:`~repro.core.plan.ShardPlan` (capacities:
        ``plan.c_pair``, ``plan.out_cap``, ``plan.d``).
    Raises:
        ValueError: naming the offending argument — ``axis`` spanning
            fewer than 2 devices, ``n_global`` not divisible by D or
            exceeding the int32 payload budget, or (at plan-build time)
            a bad ``oversample``/``pair_align``.
    """
    axt, d = _axis_degree(mesh, axis)
    if d < 2:
        raise ValueError(
            f"make_sharded_sort axis {axis!r} spans d={d} device(s); need "
            "d >= 2 (use bucket_sort.sort on a single device)"
        )
    if n_global % d != 0:
        raise ValueError(
            f"make_sharded_sort n_global ({n_global}) must be divisible by "
            f"the axis device count d={d}"
        )
    if n_global * 16 >= 2**31:
        raise ValueError(
            f"make_sharded_sort n_global ({n_global}) exceeds the int32 "
            f"payload budget (n_global * 16 < 2**31, i.e. n_global <= "
            f"{2**27}): per-phase pad ranges are drawn from the int32 "
            "payload space"
        )
    plan = _resolve_shard_plan(
        mesh, axt, d, n_global, dtype, cfg, oversample, pair_align
    )

    def run(keys):
        if jnp.dtype(keys.dtype).name != plan.dtype_name:
            raise ValueError(
                f"keys dtype {jnp.dtype(keys.dtype).name} does not match "
                f"the shard plan's dtype {plan.dtype_name} (pass dtype= to "
                "make_sharded_sort)"
            )
        # Degradation chain (DESIGN.md §11): mesh execution -> ONE retry
        # (a failed trace is never cached, so the retry re-traces from
        # scratch) -> deterministic gather-to-host degraded sort.  The
        # outcome is recorded on ``run.last_stats``.
        site = f"collective.exchange[D={plan.d}]"
        try:
            out = _sharded_argsort(keys, mesh, plan)
            run.last_stats = {"degraded": False, "retries": 0}
            return out
        except Exception as e1:
            guard.record_degradation(
                site, "retry", "mesh execution", "mesh execution (retry)", e1)
        try:
            out = _sharded_argsort(keys, mesh, plan)
            run.last_stats = {"degraded": False, "retries": 1}
            return out
        except Exception as e2:
            guard.record_degradation(
                site, "fallback", "mesh execution",
                "gather-to-host degraded sort", e2)
        out = _degraded_host_sort(keys, plan)
        run.last_stats = {"degraded": True, "retries": 1}
        return out

    run.last_stats = {"degraded": False, "retries": 0}
    return run, plan
