"""Distributed deterministic sample sort across a TPU mesh (shard_map).

The paper is single-GPU; this module scales Algorithm 1 to chips/pods.
It is the cluster-level analogue of the paper's bucket phase, with one
extra "deal" round that restores the *guaranteed-capacity* property at
per-device-pair granularity — the property that makes the exchange a
single STATIC ``lax.all_to_all`` (XLA requires static shapes; a
randomized splitter choice admits no such bound — DESIGN.md §2).

Per-shard pipeline (axis size D, local length n_loc, oversample c):

  1. local sort            (Algorithm 1 on the shard)
  2. DEAL: element p of the local sorted run goes to device (p mod D)
     via a static all_to_all transpose.  Afterwards every device holds a
     stride-D regular sample of *every* device's sorted data.
  3. local sort of the dealt data
  4. sampling: s_loc = c*D equidistant local samples, all_gather,
     replicated sort, D-1 equidistant global splitters  (steps 3-5)
  5. splitter ranks -> per-target chunk sizes            (steps 6-7)
  6. one static all_to_all of (D, C_pair) buckets        (step 8)
  7. local sort of received buckets                      (step 9)

Capacity guarantee: global bucket t holds B_t <= n_loc * (1 + 1/c)
elements (regular sampling, unique (key, payload) pairs).  The deal
makes every device hold (b_it/D ± 1) of source i's bucket-t elements, so

    chunk(j -> t) <= B_t/D + D  <=  n_loc*(1+1/c)/D + D  =: C_pair  (static!)

Overflow is therefore impossible; tests assert max fill <= C_pair.
The result is returned padded-ragged: (out_cap,) keys/payloads per
shard plus a valid-count — the natural output of a sample sort (global
order = concatenation of valid prefixes in device order).

Keys dispatch on the ``core/key_codec`` codecs like the single-device
pipeline: ``make_sharded_sort`` accepts any codec dtype (64-bit keys
travel as two uint32 words per element through every collective; x64
mode required) and honors ``cfg.descending``.  ``sorted_shard`` itself
operates on canonical words — a bare uint32 array or a tuple of word
arrays, returned in the same structure.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.core.bucket_sort import _run_node
from repro.core.key_codec import codec_for
from repro.core.plan import build_words_plan
from repro.core.sort_config import DEFAULT_CONFIG, SortConfig, round_up
from repro.kernels import ops
from repro.kernels.bitonic import as_words, like_words

_MAXU = jnp.uint32(0xFFFFFFFF)


@dataclasses.dataclass(frozen=True)
class DistSortSpec:
    """Static geometry of a distributed sort (all trace-time ints).

    Attributes:
        axis: mesh axis name (or tuple of names) the sort spans.
        d: devices along the sort axis.
        n_local: local shard length (pre-padding).
        oversample: regular-sampling oversample factor c (bound above).
    """

    axis: str | tuple[str, ...]
    d: int  # devices along the sort axis
    n_local: int  # local shard length (pre-padding)
    oversample: int = 8

    @property
    def axis_tuple(self):
        return (self.axis,) if isinstance(self.axis, str) else tuple(self.axis)

    @property
    def s_loc(self) -> int:
        return self.oversample * self.d

    @property
    def n_pad(self) -> int:
        # Padded so the deal (multiple of d) and the equidistant sampling
        # (multiple of s_loc = oversample*d) are both exact — exact spacing
        # is what the capacity-bound proof relies on.
        return round_up(self.n_local, self.s_loc)

    @property
    def b_t(self) -> int:
        """Max global bucket size: B_t <= n_pad * (1 + 1/oversample)."""
        return self.n_pad + self.n_pad // self.oversample

    @property
    def c_pair(self) -> int:
        """Static per-pair all_to_all capacity: B_t/D + D (deal bound)."""
        return round_up(-(-self.b_t // self.d) + self.d, 8)

    @property
    def out_cap(self) -> int:
        """Static per-shard output capacity >= any bucket total B_t."""
        return min(round_up(self.b_t, 8), self.d * self.c_pair)


def _local_sort(kw, v, cfg, pad_base):
    """Plan-driven local sort: every per-shard sort builds its static
    schedule through the same ``core/plan`` builder as the single-device
    pipeline (all shard lengths are trace-time ints) and hands it to the
    plan executor."""
    p = build_words_plan(kw[0].shape[0], len(kw), cfg)
    skw, sv, _ = _run_node(
        tuple(w[None, :] for w in kw), v[None, :], p.root, p.impl,
        p.interpret, pad_base, None,
    )
    return tuple(w[0] for w in skw), sv[0]


def _deal_all_to_all(x, ax, d, n_pad):
    """Deal: position p -> device p mod D (static transpose all_to_all)."""
    x = jnp.swapaxes(x.reshape(n_pad // d, d), 0, 1)  # (D, n_pad/D) strided
    return jax.lax.all_to_all(x, ax, split_axis=0, concat_axis=0, tiled=False)


def sorted_shard(
    keys_local,
    vals_local: jax.Array,
    spec: DistSortSpec,
    cfg: SortConfig = DEFAULT_CONFIG,
):
    """Distributed sort body — call INSIDE shard_map over ``spec.axis``.

    Args:
        keys_local: (n_local,) canonical uint32 key words — bare array
            or tuple of word arrays (msw first, see ``core/key_codec``).
        vals_local: (n_local,) int32 payloads, globally unique (use
            global indices).
        spec: static geometry (see :class:`DistSortSpec`).
        cfg: pipeline knobs for the local sorts.
    Returns:
        (keys (out_cap,) in the input structure, vals (out_cap,),
        count (), max_within ()) — valid prefix of each shard; shards
        concatenated in device order form the globally sorted sequence.
    """
    kw = as_words(keys_local)
    ax = spec.axis
    d, n_pad, s_loc, c_pair = spec.d, spec.n_pad, spec.s_loc, spec.c_pair
    n_glob = n_pad * d
    pad_base = n_glob  # payloads are global indices < n_glob

    me = jax.lax.axis_index(ax)
    # Pad shard to a multiple of D with unique (all-ones, >= n_glob) pads.
    n0 = kw[0].shape[0]
    pad_n = n_pad - n0
    if pad_n:
        pk = jnp.full((pad_n,), _MAXU, jnp.uint32)
        pv = n_glob + me * pad_n + jnp.arange(pad_n, dtype=jnp.int32)
        kw = tuple(jnp.concatenate([w, pk]) for w in kw)
        vals_local = jnp.concatenate([vals_local, pv])
    v = vals_local
    pad_base += d * n_pad

    # 1. local sort
    kw, v = _local_sort(kw, v, cfg, pad_base)
    pad_base += 4 * n_glob  # disjoint pad range headroom per phase

    # 2. deal: one static all_to_all transpose per word + payload
    kw = tuple(_deal_all_to_all(w, ax, d, n_pad).reshape(n_pad) for w in kw)
    v = _deal_all_to_all(v, ax, d, n_pad).reshape(n_pad)

    # 3. local sort of dealt data
    kw, v = _local_sort(kw, v, cfg, pad_base)
    pad_base += 4 * n_glob

    # 4. sampling -> replicated splitters (steps 3-5 of Algorithm 1)
    samp_idx = (jnp.arange(1, s_loc + 1, dtype=jnp.int32) * (n_pad // s_loc)) - 1
    skw_all = tuple(
        jax.lax.all_gather(w[samp_idx], ax).reshape(d * s_loc) for w in kw
    )
    sv_all = jax.lax.all_gather(v[samp_idx], ax).reshape(d * s_loc)
    sskw, ssv = _local_sort(skw_all, sv_all, cfg, pad_base)
    pad_base += 4 * d * s_loc
    sp_idx = (jnp.arange(1, d, dtype=jnp.int32) * (d * s_loc)) // d
    spkw = tuple(w[sp_idx] for w in sskw)  # (D-1,) identical on every device
    spv = ssv[sp_idx]

    # 5. splitter ranks -> chunk geometry (steps 6-7)
    ranks = ops.splitter_ranks(
        tuple(w[None, :] for w in kw), v[None, :],
        tuple(w[None, :] for w in spkw), spv[None, :],
        impl=cfg.impl, interpret=cfg.interpret,
    )[0]  # (D-1,) in [0, n_pad]
    starts = jnp.concatenate([jnp.zeros((1,), jnp.int32), ranks])
    ends = jnp.concatenate([ranks, jnp.full((1,), n_pad, jnp.int32)])
    counts = ends - starts  # (D,) elements per target device

    # 6. scatter into the padded (D, C_pair) buffer, one static all_to_all
    pos = jnp.arange(n_pad, dtype=jnp.int32)
    ind = jnp.zeros((n_pad + 1,), jnp.int32).at[ranks].add(1)
    chunk_id = jnp.cumsum(ind, dtype=jnp.int32)[:n_pad]
    within = pos - jnp.take(starts, chunk_id)
    max_within = jnp.max(within)  # bound check: < C_pair (tested)
    dest = chunk_id * c_pair + within
    dest = jnp.where(within < c_pair, dest, d * c_pair)
    bkw = tuple(
        jnp.full((d * c_pair,), _MAXU, jnp.uint32).at[dest].set(w, mode="drop")
        for w in kw
    )
    bv = (
        jnp.int32(pad_base) + jnp.arange(d * c_pair, dtype=jnp.int32)
    ).at[dest].set(v, mode="drop")
    pad_base += d * d * c_pair

    bkw = tuple(
        jax.lax.all_to_all(
            w.reshape(d, c_pair), ax, split_axis=0, concat_axis=0, tiled=False
        )
        for w in bkw
    )
    bv = jax.lax.all_to_all(
        bv.reshape(d, c_pair), ax, split_axis=0, concat_axis=0, tiled=False
    )
    recv_counts = jax.lax.all_to_all(
        counts.reshape(d, 1), ax, split_axis=0, concat_axis=0, tiled=False
    ).reshape(d)

    # 7. local sort of the received buckets (step 9); reals sort before pads
    fkw, fv = _local_sort(
        tuple(w.reshape(d * c_pair) for w in bkw), bv.reshape(d * c_pair),
        cfg, pad_base,
    )
    out_cap = spec.out_cap
    count = jnp.sum(recv_counts, dtype=jnp.int32)
    # Padded shard elements (payload in [n_glob, n_glob + d*n_pad)) are real
    # inputs' pads: they sort after all true elements; exclude them.
    count = count - jnp.sum(
        (fv[:out_cap] >= n_glob) & (fv[:out_cap] < n_glob + d * n_pad),
        dtype=jnp.int32,
    )
    return (
        like_words(tuple(w[:out_cap] for w in fkw), keys_local),
        fv[:out_cap],
        count,
        max_within,
    )


def make_sharded_sort(
    mesh, axis, n_global: int, cfg: SortConfig = DEFAULT_CONFIG,
    oversample: int = 8,
):
    """Build a jit'd distributed argsort over ``axis`` of ``mesh``.

    Args:
        mesh: jax device mesh.
        axis: mesh axis name (or tuple) to sort across; D = its size.
        n_global: total key count (must divide by D).
        cfg: pipeline knobs (``descending`` supported; keys of any codec
            dtype — 64-bit needs x64 mode).
        oversample: regular-sampling oversample factor.
    Returns:
        (fn, spec) where fn: (keys (n_global,) sharded over axis) ->
          (sorted_keys (D*out_cap,), payload_idx (D*out_cap,),
           counts (D,), max_within (D,))
        and the valid prefix of each shard (counts[i] elements)
        concatenated in shard order is the globally sorted sequence;
        payloads are original global indices (an argsort).
    """
    axt = (axis,) if isinstance(axis, str) else tuple(axis)
    d = 1
    for a in axt:
        d *= mesh.shape[a]
    assert d >= 2, "use bucket_sort.sort for a single device"
    assert n_global % d == 0, (n_global, d)
    assert n_global * 16 < 2**31, "int32 payload budget caps global n at ~2^27"
    spec = DistSortSpec(axis=axis, d=d, n_local=n_global // d, oversample=oversample)

    def body(keys_local):
        n_loc = spec.n_local
        me = jax.lax.axis_index(axis)
        codec = codec_for(keys_local.dtype, cfg.descending)
        kw = codec.encode(keys_local)
        gid = me * n_loc + jnp.arange(n_loc, dtype=jnp.int32)
        fkw, fv, count, max_within = sorted_shard(kw, gid, spec, cfg)
        # Stack words into one (nw, out_cap) array so the shard_map
        # out_specs stay structure-independent of the codec word count.
        return (
            jnp.stack(fkw)[None],
            fv[None],
            count[None],
            max_within[None],
        )

    pspec = P(axt)

    @jax.jit
    def run(keys):
        codec = codec_for(keys.dtype, cfg.descending)
        fkw, fv, counts, mw = shard_map(
            body,
            mesh=mesh,
            in_specs=(pspec,),
            out_specs=(P(axt, None, None), P(axt, None), pspec, pspec),
        )(keys)
        # fkw: (D, nw, out_cap) -> per-word (D*out_cap,) flats -> decode
        words = tuple(
            fkw[:, i, :].reshape(-1) for i in range(codec.num_words)
        )
        return (
            codec.decode(words),
            fv.reshape(-1),
            counts,
            mw,
        )

    return run, spec
