"""Deterministic fault injection for the guarded execution subsystem.

The engine's degradation chains (DESIGN.md §11) only earn trust if they
are exercised: this module lets tests (and operators) fail any *named
fault site* on a chosen hit, deterministically.  Production code calls
``faults.check("<site>")`` at each fallible site; with no injection
rules armed the call is a dict lookup and an integer increment.

Sites are a closed registry (``SITES``) so a typo in either the
instrumentation or a test is an immediate ``ValueError`` rather than a
silently-never-firing rule.

Two ways to arm a rule:

* ``with faults.inject("kernel.launch", on_hit=1, count=2): ...`` —
  scoped, resets the site's hit counter on entry so ``on_hit`` is
  relative to the block.
* ``REPRO_SORT_FAULTS="kernel.launch:1:2,cache.load:1"`` — process-wide,
  parsed once (``site:on_hit[:count]``, comma-separated).

Both are deterministic: rule ``(on_hit=h, count=c)`` fails exactly hits
``h .. h+c-1`` of its site.  A seeded probabilistic mode
(``inject(site, prob=0.5, seed=7)``) uses a private ``random.Random``
per rule, so two runs with the same seed fire on the same hits.

Counters are lock-protected: the ``pipeline.producer`` site is hit from
a background thread.
"""
from __future__ import annotations

import contextlib
import os
import random
import threading
from typing import Iterator

__all__ = [
    "SITES",
    "FaultInjected",
    "check",
    "inject",
    "hits",
    "reset",
]

#: Closed registry of named fault sites (see DESIGN.md §11 for the map
#: from site to degradation chain).
SITES = (
    "kernel.launch",        # tile-sort kernel dispatch (kernels/ops.py)
    "cache.load",           # plan-cache store read (core/autotune.py)
    "cache.save",           # plan-cache store persist (core/autotune.py)
    "autotune.measure",     # candidate measurement (core/autotune.py)
    "collective.exchange",  # mesh all-to-all (core/distributed_sort.py)
    "pipeline.producer",    # prefetch thread body (data/pipeline.py)
)

_ENV = "REPRO_SORT_FAULTS"


class FaultInjected(RuntimeError):
    """Raised by :func:`check` when an armed rule matches the current hit.

    Attributes:
      site: the fault-site name that fired.
      hit: the 1-based hit number at which it fired.
    """

    def __init__(self, site: str, hit: int):
        super().__init__(f"injected fault at site {site!r} (hit {hit})")
        self.site = site
        self.hit = hit


class _Rule:
    __slots__ = ("site", "on_hit", "count", "prob", "_rng", "fired")

    def __init__(self, site: str, on_hit: int = 1, count: int = 1,
                 prob: float | None = None, seed: int = 0):
        _validate_site(site)
        if on_hit < 1:
            raise ValueError(f"on_hit must be >= 1, got {on_hit}")
        if count < 1:
            raise ValueError(f"count must be >= 1, got {count}")
        if prob is not None and not (0.0 <= prob <= 1.0):
            raise ValueError(f"prob must be in [0, 1], got {prob}")
        self.site = site
        self.on_hit = on_hit
        self.count = count
        self.prob = prob
        self._rng = random.Random(seed) if prob is not None else None
        self.fired = 0

    def matches(self, hit: int) -> bool:
        if self.prob is not None:
            return self._rng.random() < self.prob
        return self.on_hit <= hit < self.on_hit + self.count


_lock = threading.RLock()
_hits: dict[str, int] = {}
_rules: list[_Rule] = []
_env_rules: list[_Rule] | None = None  # parsed lazily, invalidated by reset()


def _validate_site(site: str) -> None:
    if site not in SITES:
        raise ValueError(
            f"unknown fault site {site!r}; registered sites: {', '.join(SITES)}")


def _parse_env(spec: str) -> list[_Rule]:
    rules: list[_Rule] = []
    for part in spec.split(","):
        part = part.strip()
        if not part:
            continue
        fields = part.split(":")
        site = fields[0]
        try:
            on_hit = int(fields[1]) if len(fields) > 1 else 1
            count = int(fields[2]) if len(fields) > 2 else 1
        except ValueError as e:
            raise ValueError(
                f"bad {_ENV} entry {part!r}: expected site:on_hit[:count]"
            ) from e
        rules.append(_Rule(site, on_hit=on_hit, count=count))
    return rules


def check(site: str) -> None:
    """Record one hit at ``site``; raise :class:`FaultInjected` if armed.

    Called by production code at every fallible site.  No-op (beyond the
    counter) unless a matching :func:`inject` rule or ``REPRO_SORT_FAULTS``
    entry is active.
    """
    _validate_site(site)
    global _env_rules
    with _lock:
        if _env_rules is None:
            _env_rules = _parse_env(os.environ.get(_ENV, ""))
        hit = _hits.get(site, 0) + 1
        _hits[site] = hit
        for rule in _rules + _env_rules:
            if rule.site == site and rule.matches(hit):
                rule.fired += 1
                raise FaultInjected(site, hit)


def hits(site: str) -> int:
    """Total hits recorded at ``site`` since the last reset."""
    _validate_site(site)
    with _lock:
        return _hits.get(site, 0)


def reset() -> None:
    """Clear all hit counters, scoped rules, and the env-rule cache."""
    global _env_rules
    with _lock:
        _hits.clear()
        _rules.clear()
        _env_rules = None


@contextlib.contextmanager
def inject(site: str, *, on_hit: int = 1, count: int = 1,
           prob: float | None = None, seed: int = 0) -> Iterator[_Rule]:
    """Arm a deterministic fault at ``site`` for the duration of the block.

    The site's hit counter is reset on entry, so ``on_hit=n`` means "the
    n-th hit inside this block".  ``count`` consecutive hits fail starting
    at ``on_hit``; pass a large count to fail every hit.  ``prob``/``seed``
    switch to seeded probabilistic firing (still reproducible).  Yields the
    rule; ``rule.fired`` counts how many times it actually raised.
    """
    rule = _Rule(site, on_hit=on_hit, count=count, prob=prob, seed=seed)
    with _lock:
        _hits[site] = 0
        _rules.append(rule)
    try:
        yield rule
    finally:
        with _lock:
            _rules.remove(rule)
