"""Sort-plan IR: the static schedule of GPU BUCKET SORT as data.

The paper's deterministic regular sampling makes every quantity of the
multi-level pipeline a *static* function of ``(shape, dtype, config)``:
recursion levels, per-level ``rows x tile`` geometry, ``s_round``,
bucket capacities, pad budgets, kernel block sizes, fusion and
relocation choices.  Nothing is data-dependent — that is the theorem
that lets the whole sort run under XLA's static shapes (DESIGN.md §2).

This module reifies that schedule as a frozen, hashable IR
(:class:`SortPlan` / :class:`LevelPlan`) computed ONCE by
:func:`build_plan` and merely *walked* by the executor in
``core/bucket_sort.py``.  The split buys three things (DESIGN.md §7):

  * the executor's step functions take plan fields instead of
    re-deriving geometry, so one mechanism drives the 1-D, batched,
    segmented, partial (top-k) and distributed entry points;
  * plans are jit static arguments — equal plans hit the same compiled
    executable, so a plan-cache hit means ZERO retraces;
  * plans serialize (:func:`plan_to_dict` / :func:`plan_from_dict`)
    byte-stably, which is what the ``core/autotune.py`` persistent plan
    cache stores and reloads.

``build_plan`` is pure and deterministic: the same
``(length, dtype, cfg, rows)`` produces a byte-identical plan
(property-tested in ``tests/test_plan.py``).  The only environment
inputs are the resolved backend/impl/interpret defaults, which are part
of the plan's identity (and of the autotune cache key).
"""

from __future__ import annotations

import dataclasses
import functools
import hashlib
import json

import jax

from repro.core.key_codec import codec_for
from repro.core.sort_config import SortConfig, next_pow2, round_up

# Static recursion depth guard: the level count shrinks geometrically
# (cap < lp and m*s < lp for s < tile), so real plans are < 8 levels
# deep; hitting this means a degenerate config (e.g. s == tile with
# length > direct_max, where the sample array never shrinks).
_MAX_DEPTH = 64


@dataclasses.dataclass(frozen=True)
class LevelPlan:
    """One node of the static recursion tree (all trace-time ints).

    ``kind == "direct"``: single-tile bitonic sort of each (rows, lp)
    row, lp = next_pow2(length).  ``kind == "bucket"``: one bucket
    round — local tile sort, sample recursion (``sample_plan``),
    splitter partition, relocation into the dense (rows*s_round, cap)
    bucket array, bucket recursion (``bucket_plan``), compaction.

    Attributes:
        kind: "direct" | "bucket".
        rows: row count entering this level.
        length: row length entering this level (pre-padding).
        lp: padded row length (direct: next power of two; bucket:
            rounded up to a tile multiple).
        block_rows: resolved tiles-per-grid-program for the level's
            bitonic sort (None on the xla path) — plan-carried kernel
            geometry, already a power-of-two divisor of the tile count.
        tile / s: the level's tile width T and samples per tile
            (bucket levels only; 0 for direct).
        m: tiles per row (lp // tile).
        s_round: buckets this round (equidistant global splitters + 1).
        cap: static per-bucket capacity — the paper's regular-sampling
            bound round_up(lp/s_round + lp/s, 128) (DESIGN.md §2).
        part_block_rows: resolved block size of the fused
            splitter-partition kernel (None when unfused / xla).
        fuse_sampling / fuse_ranking / relocation: per-level pipeline
            choices (today uniform across levels, copied from cfg).
        strategy: the level's local-sort algorithm ("bitonic" | "radix"
            | "merge") — a PER-LEVEL plan field (DESIGN.md §8); the
            executor dispatches ``ops.sort_tiles`` on it.
        radix_bits / merge_run: strategy knobs carried alongside
            (consulted only by the matching strategy).
        sample_plan: step-4 recursion on the (rows, m*s) sample array.
        bucket_plan: step-9 recursion on the (rows*s_round, cap)
            bucket rows.
    """

    kind: str
    rows: int
    length: int
    lp: int
    block_rows: int | None
    tile: int = 0
    s: int = 0
    m: int = 0
    s_round: int = 0
    cap: int = 0
    part_block_rows: int | None = None
    fuse_sampling: bool = False
    fuse_ranking: bool = False
    relocation: str = "gather"
    strategy: str = "bitonic"
    radix_bits: int = 4
    merge_run: int = 512
    sample_plan: "LevelPlan | None" = None
    bucket_plan: "LevelPlan | None" = None

    # -- cost-relevant derived geometry (properties, not serialized;
    #    core/cost_model.py reads these instead of re-deriving) --------

    @property
    def elements(self) -> int:
        """Padded elements entering this level (rows * lp)."""
        return self.rows * self.lp

    @property
    def tiles(self) -> int:
        """Tile count of the level's local sort (bucket: rows * m)."""
        return self.rows * self.m if self.kind == "bucket" else self.rows

    @property
    def sample_elements(self) -> int:
        """Step-3 sample array size this level emits (0 for direct)."""
        return self.rows * self.m * self.s if self.kind == "bucket" else 0

    @property
    def bucket_elements(self) -> int:
        """Dense bucket-array size after relocation (0 for direct)."""
        if self.kind != "bucket":
            return 0
        return self.rows * self.s_round * self.cap


@dataclasses.dataclass(frozen=True)
class SortPlan:
    """The full static schedule of one sort signature.

    Frozen and hashable: used as a jit static argument, so two calls
    carrying equal plans share one compiled executable.

    Attributes:
        rows: entry row count (1 for the 1-D API, B for batched).
        length: entry row length L.
        dtype_name: canonical key dtype name (``jnp.dtype(...).name``).
        num_words: uint32 key words per element (codec).
        descending: order baked into the key codec.
        impl: resolved implementation ("pallas" | "xla").
        interpret: resolved Pallas interpret mode.
        backend: jax.default_backend() at build time (cache key part).
        rows_padded: rows after batch row-padding (== rows unless the
            batched pallas path pads to a cfg.row_pad multiple).
        cfg_fingerprint: stable hash of the generating config (every
            field except ``plan`` — see :func:`config_fingerprint`).
        root: the level tree the executor walks.
    """

    rows: int
    length: int
    dtype_name: str
    num_words: int
    descending: bool
    impl: str
    interpret: bool
    backend: str
    rows_padded: int
    cfg_fingerprint: str
    root: LevelPlan

    @property
    def bytes_per_element(self) -> int:
        """HBM bytes one element occupies on the hot path: the key
        words plus the int32 payload word (cost-model input)."""
        return 4 * (self.num_words + 1)

    @property
    def num_levels(self) -> int:
        """Bucket rounds on the main (bucket_plan) spine."""
        n, node = 0, self.root
        while node is not None and node.kind == "bucket":
            n += 1
            node = node.bucket_plan
        return n

    def signature(self) -> tuple:
        """The cache identity: (shape, dtype, backend, cfg-fingerprint)."""
        return (
            self.rows,
            self.length,
            self.dtype_name,
            self.descending,
            self.impl,
            self.interpret,
            self.backend,
            self.cfg_fingerprint,
        )

    def describe(self) -> str:
        """Human-readable one-plan summary (levels and geometry)."""
        lines = [
            f"SortPlan(rows={self.rows}->{self.rows_padded}, "
            f"length={self.length}, dtype={self.dtype_name}"
            f"{' desc' if self.descending else ''}, impl={self.impl}, "
            f"levels={self.num_levels})"
        ]
        node, depth = self.root, 0
        while node is not None:
            if node.kind == "direct":
                lines.append(
                    f"  L{depth}: direct rows={node.rows} lp={node.lp} "
                    f"block_rows={node.block_rows} strategy={node.strategy}"
                )
                break
            lines.append(
                f"  L{depth}: bucket rows={node.rows} lp={node.lp} "
                f"tile={node.tile} s={node.s} m={node.m} "
                f"s_round={node.s_round} cap={node.cap} "
                f"block_rows={node.block_rows} reloc={node.relocation} "
                f"strategy={node.strategy}"
            )
            node = node.bucket_plan
            depth += 1
        return "\n".join(lines)


def config_fingerprint(cfg: SortConfig) -> str:
    """Stable hash of every SortConfig field except ``plan`` and ``check``.

    The ``plan`` field selects HOW a plan is obtained (default /
    autotune / file); it must not perturb the identity of the plans the
    cache is keyed by, or a cached plan could never match the config
    that requests it.  ``check`` is a call-time verification knob
    (``core/guard.py``) that never changes the schedule: excluding it
    keeps checked and unchecked runs on the same cache entries (and
    keeps fingerprints stable across the field's introduction).
    """
    d = dataclasses.asdict(cfg)
    d.pop("plan", None)
    d.pop("check", None)
    blob = json.dumps(d, sort_keys=True, default=str)
    return hashlib.sha256(blob.encode()).hexdigest()[:16]


def _resolve_backend(cfg: SortConfig) -> tuple[str, bool, str]:
    """(impl, interpret, backend) with the cfg Nones resolved."""
    from repro.kernels import ops  # local import: ops imports core.key_codec

    impl = cfg.impl or ops.default_impl()
    interpret = (
        ops.default_interpret() if cfg.interpret is None else cfg.interpret
    )
    return impl, interpret, jax.default_backend()


def _sort_block_rows(
    impl: str, tiles: int, t: int, cfg_block_rows: int | None, nw: int
) -> int | None:
    from repro.kernels import bitonic

    if impl != "pallas":
        return None
    return bitonic.effective_block_rows(tiles, t, cfg_block_rows, num_words=nw)


def _build_node(
    rows: int, length: int, cfg: SortConfig, impl: str, nw: int, depth: int
) -> LevelPlan:
    if depth > _MAX_DEPTH:
        raise ValueError(
            "sort-plan recursion exceeded depth "
            f"{_MAX_DEPTH} at (rows={rows}, length={length}); degenerate "
            "config (s == tile with length > direct_max never shrinks "
            "the sample array)"
        )
    if length <= cfg.direct_max:
        lp = next_pow2(length)
        return LevelPlan(
            kind="direct",
            rows=rows,
            length=length,
            lp=lp,
            block_rows=_sort_block_rows(impl, rows, lp, cfg.block_rows, nw),
            strategy=cfg.strategy,
            radix_bits=cfg.radix_bits,
            merge_run=cfg.merge_run,
        )

    t, sper = cfg.tile, cfg.s
    lp = round_up(length, t)
    m = lp // t
    # Step 5: s_round - 1 equidistant global splitters (s_round buckets).
    s_round = min(max(next_pow2(-(-2 * lp // t)), 2), sper)
    # The paper's guaranteed capacity (DESIGN.md §2), lane-aligned.
    cap = round_up(lp // s_round + lp // sper, 128)
    part_block_rows = None
    if impl == "pallas" and cfg.fuse_ranking:
        from repro.kernels import splitter

        part_block_rows = splitter.partition_block_rows(
            rows * m, t, s_round - 1, num_words=nw
        )
    return LevelPlan(
        kind="bucket",
        rows=rows,
        length=length,
        lp=lp,
        block_rows=_sort_block_rows(impl, rows * m, t, cfg.block_rows, nw),
        tile=t,
        s=sper,
        m=m,
        s_round=s_round,
        cap=cap,
        part_block_rows=part_block_rows,
        fuse_sampling=cfg.fuse_sampling,
        fuse_ranking=cfg.fuse_ranking,
        relocation=cfg.relocation,
        strategy=cfg.strategy,
        radix_bits=cfg.radix_bits,
        merge_run=cfg.merge_run,
        sample_plan=_build_node(rows, m * sper, cfg, impl, nw, depth + 1),
        bucket_plan=_build_node(
            rows * s_round, cap, cfg, impl, nw, depth + 1
        ),
    )


@functools.lru_cache(maxsize=512)
def _assemble_plan(
    rows: int,
    length: int,
    dtype_name: str,
    nw: int,
    descending: bool,
    cfg: SortConfig,
    pad_rows: bool,
    impl: str,
    interpret: bool,
    backend: str,
) -> SortPlan:
    """Memoized plan assembly: the cache key includes the RESOLVED
    backend triple, so a changed env/backend can never serve a stale
    plan, while repeated calls return the SAME object (fast jit static
    lookups)."""
    rows_padded = rows
    if pad_rows and impl == "pallas" and cfg.row_pad > 1 and rows > 0:
        rows_padded = round_up(rows, cfg.row_pad)
    return SortPlan(
        rows=rows,
        length=length,
        dtype_name=dtype_name,
        num_words=nw,
        descending=descending,
        impl=impl,
        interpret=interpret,
        backend=backend,
        rows_padded=rows_padded,
        cfg_fingerprint=config_fingerprint(cfg),
        root=_build_node(max(rows_padded, 1), length, cfg, impl, nw, 0),
    )


def build_plan(
    length: int,
    dtype,
    cfg: SortConfig,
    *,
    rows: int = 1,
    pad_rows: bool = False,
) -> SortPlan:
    """Compute the full static schedule for one sort signature.

    Pure and deterministic: equal inputs produce equal (byte-identical
    once serialized) plans.  Called once per signature (memoized); the
    executor in ``core/bucket_sort.py`` only walks the result.

    Args:
        length: row length L (the 1-D array length, or the row width of
            the batched/segmented packed array).
        dtype: key dtype (any ``core/key_codec`` dtype).
        cfg: pipeline knobs; ``cfg.descending`` is baked into the plan
            identity, ``cfg.plan`` is NOT (it selects how plans are
            obtained, see :func:`config_fingerprint`).
        rows: entry row count (1 for the 1-D API, B for batched).
        pad_rows: apply the batched-path row padding to a multiple of
            ``cfg.row_pad`` (DESIGN.md §5) — the batched/segmented
            entry points pass True, the 1-D path False.
    Returns:
        A frozen :class:`SortPlan`.

    Example:
        >>> from repro.core.plan import build_plan
        >>> from repro.core.sort_config import SortConfig
        >>> p = build_plan(100_000, "int32", SortConfig(impl="xla"))
        >>> (p.length, p.root.kind, p.num_levels >= 1)
        (100000, 'bucket', True)
    """
    import jax.numpy as jnp

    codec = codec_for(dtype, cfg.descending)
    impl, interpret, backend = _resolve_backend(cfg)
    return _assemble_plan(
        rows, length, jnp.dtype(dtype).name, codec.num_words,
        cfg.descending, cfg, pad_rows, impl, interpret, backend,
    )


def build_words_plan(
    length: int,
    num_words: int,
    cfg: SortConfig,
    *,
    rows: int = 1,
    pad_rows: bool = False,
) -> SortPlan:
    """Plan for callers already holding CANONICAL uint32 key words
    (``distributed_sort.sorted_shard``, the recursion shims): the
    canonical domain is always ascending, so there is no dtype/codec —
    only the word count matters for geometry."""
    impl, interpret, backend = _resolve_backend(cfg)
    return _assemble_plan(
        rows, length, f"uint32x{num_words}", num_words, False, cfg,
        pad_rows, impl, interpret, backend,
    )


# ----------------------------------------------------------------------
# Partial-sort (top-k) plan: the one-bucket-round schedule
# ----------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TopkPlan:
    """Static schedule of the partial sort (one bucket round, steps 1-7
    + candidate pack + candidate sort — ``core/partial_sort.py``).

    Attributes:
        rows: batch rows (1 for the 1-D entry).
        length: scores per row (n / vocab).
        k: requested top-k.
        lp: length padded to a tile multiple.
        m: tiles per row.
        tile / s: tile width and samples per tile.
        cap: the bucket-capacity bound round_up(2*lp/s, 128) the
            threshold argument relies on.
        ccap: static candidate-buffer width round_up(min(k+cap, lp), 128).
        block_rows: resolved tile-sort block size (None on xla).
        raw_block_rows: the unresolved cfg knob, carried as the UPPER
            BOUND for the small sample/candidate sorts (whose padded
            widths the kernels clamp against).
        direct_max: lengths up to this take the direct single-tile path.
        strategy / radix_bits / merge_run: the local-sort strategy for
            the tile/candidate sorts, copied from the cfg (DESIGN.md
            §8; the candidate packs preserve the payload invariant the
            non-bitonic strategies rely on).
        impl / interpret / backend: resolved as in :class:`SortPlan`.
    """

    rows: int
    length: int
    k: int
    lp: int
    m: int
    tile: int
    s: int
    cap: int
    ccap: int
    block_rows: int | None
    raw_block_rows: int | None
    direct_max: int
    impl: str
    interpret: bool
    backend: str
    strategy: str = "bitonic"
    radix_bits: int = 4
    merge_run: int = 512

    @property
    def elements(self) -> int:
        """Padded elements entering the bucket round (rows * lp)."""
        return max(self.rows, 1) * self.lp

    @property
    def candidate_elements(self) -> int:
        """Candidate-buffer elements of the final pack (rows * ccap)."""
        return max(self.rows, 1) * self.ccap


@functools.lru_cache(maxsize=512)
def _assemble_topk_plan(
    length: int, k: int, nw: int, cfg: SortConfig, rows: int,
    impl: str, interpret: bool, backend: str,
) -> TopkPlan:
    """Memoized topk-plan assembly; like :func:`_assemble_plan`, the
    RESOLVED backend triple is part of the cache key so a changed
    env/backend can never serve a stale plan."""
    t, sper = cfg.tile, cfg.s
    lp = round_up(length, t)
    m = lp // t
    cap = round_up(2 * lp // sper, 128)
    ccap = round_up(min(k + cap, lp), 128)
    return TopkPlan(
        rows=rows,
        length=length,
        k=k,
        lp=lp,
        m=m,
        tile=t,
        s=sper,
        cap=cap,
        ccap=ccap,
        block_rows=_sort_block_rows(
            impl, max(rows, 1) * m, t, cfg.block_rows, nw
        ),
        raw_block_rows=cfg.block_rows,
        direct_max=cfg.direct_max,
        impl=impl,
        interpret=interpret,
        backend=backend,
        strategy=cfg.strategy,
        radix_bits=cfg.radix_bits,
        merge_run=cfg.merge_run,
    )


def build_topk_plan(
    length: int, k: int, dtype, cfg: SortConfig, *, rows: int = 1
) -> TopkPlan:
    """Static schedule for :func:`repro.core.partial_sort.topk`.

    Same builder conventions as :func:`build_plan` (pure,
    deterministic, backend-resolved, memoized).  Lengths <=
    cfg.direct_max take the direct path and never consult the bucket
    fields.
    """
    codec = codec_for(dtype, descending=True)
    impl, interpret, backend = _resolve_backend(cfg)
    return _assemble_topk_plan(
        length, k, codec.num_words, cfg, rows, impl, interpret, backend
    )


# ----------------------------------------------------------------------
# ShardPlan: the distributed (multi-device) schedule as an IR node
# ----------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShardGeometry:
    """Static capacity arithmetic of the distributed deal-round sort
    (all trace-time ints; the one source of truth for the bound —
    ``DistSortSpec`` and :func:`build_shard_plan` both read it).

    Derivation (DESIGN.md §9): regular sampling bounds every global
    bucket at ``b_t <= n_pad * (1 + 1/oversample)``; the deal round
    spreads each source's contribution to bucket t evenly over the D
    devices (±1), so the per-device-pair chunk is bounded by the STATIC
    ``c_pair = ceil(b_t / d) + d`` (lane-aligned to ``pair_align``) and
    the exchange is one fixed-shape ``all_to_all``.

    Attributes:
        n_local: local shard length (pre-padding).
        d: devices along the sort axis.
        oversample: regular-sampling oversample factor c.
        pair_align: lane-alignment multiple of the c_pair capacity
            (the exchange-tiling knob the autotuner searches).
        s_loc: local samples per shard (= oversample * d).
        n_pad: shard length padded so the deal (multiple of d) and the
            equidistant sampling (multiple of s_loc) are both exact.
        b_t: max global bucket size, n_pad * (1 + 1/oversample).
        c_pair: static per-pair all_to_all capacity.
        out_cap: static per-shard output capacity >= any bucket total.
    """

    n_local: int
    d: int
    oversample: int
    pair_align: int
    s_loc: int
    n_pad: int
    b_t: int
    c_pair: int
    out_cap: int


def shard_geometry(
    n_local: int, d: int, oversample: int = 8, pair_align: int = 8
) -> ShardGeometry:
    """Compute the static distributed-sort geometry (validated).

    Raises:
        ValueError: naming the offending argument, matching the
            ``SortConfig.__post_init__`` convention — ``oversample``
            must be a power of two >= 1 (so ``s_loc = oversample * d``
            stays power-of-two-compatible with the power-of-two device
            meshes the deal targets), ``pair_align`` a power of two
            >= 8, ``n_local`` >= 1.

    Example:
        >>> from repro.core.plan import shard_geometry
        >>> g = shard_geometry(n_local=1000, d=4, oversample=8)
        >>> (g.s_loc, g.n_pad, g.b_t, g.c_pair >= g.b_t // 4 + 4)
        (32, 1024, 1152, True)
    """
    if not (isinstance(n_local, int) and n_local >= 1):
        raise ValueError(
            f"shard_geometry n_local must be an int >= 1, got {n_local!r}"
        )
    if not (isinstance(d, int) and d >= 2):
        raise ValueError(
            f"shard_geometry d must be an int >= 2 (devices along the "
            f"sort axis), got {d!r}"
        )
    if not (
        isinstance(oversample, int)
        and oversample >= 1
        and oversample & (oversample - 1) == 0
    ):
        raise ValueError(
            "oversample must be a power of two >= 1 (keeps s_loc = "
            f"oversample * d power-of-two-compatible), got {oversample!r}"
        )
    if not (
        isinstance(pair_align, int)
        and pair_align >= 8
        and pair_align & (pair_align - 1) == 0
    ):
        raise ValueError(
            f"pair_align must be a power of two >= 8, got {pair_align!r}"
        )
    s_loc = oversample * d
    n_pad = round_up(n_local, s_loc)
    b_t = n_pad + n_pad // oversample
    c_pair = round_up(-(-b_t // d) + d, pair_align)
    out_cap = min(round_up(b_t, 8), d * c_pair)
    return ShardGeometry(
        n_local=n_local,
        d=d,
        oversample=oversample,
        pair_align=pair_align,
        s_loc=s_loc,
        n_pad=n_pad,
        b_t=b_t,
        c_pair=c_pair,
        out_cap=out_cap,
    )


@dataclasses.dataclass(frozen=True)
class ShardPlan:
    """The full static schedule of one DISTRIBUTED sort signature.

    Frozen and hashable — the jit static argument of the distributed
    executor (``core/distributed_sort._sharded_argsort``): equal
    ``(shape, mesh, dtype, plan)`` signatures share one compiled
    executable, exactly as :class:`SortPlan` does for the single-device
    path (trace-count discipline tested in ``tests/test_distributed``).

    Attributes:
        axis: mesh axis name tuple the sort spans (1 or 2 axes).
        d: devices along the sort axis (product over ``axis``).
        n_local / n_pad: shard length before/after deal+sampling padding.
        oversample: regular-sampling oversample factor c.
        pair_align: lane alignment of the per-pair exchange capacity
            (the exchange-tiling knob; part of ``c_pair``).
        s_loc: local samples per shard (oversample * d).
        b_t: max global bucket size, n_pad * (1 + 1/oversample).
        c_pair: STATIC per-pair all_to_all capacity (DESIGN.md §9).
        out_cap: static per-shard output capacity (>= any bucket total).
        dtype_name / num_words / descending: key codec identity.
        impl / interpret / backend: resolved as in :class:`SortPlan`.
        cfg_fingerprint: stable hash of the generating config.
        run_plan: phase-1 local sort of the (1, n_pad) shard.
        dealt_plan: phase-3 local sort of the dealt (1, n_pad) run.
        sample_plan: replicated sort of the (1, d*s_loc) gathered
            samples.
        bucket_plan: phase-7 local sort of the received (1, d*c_pair)
            buckets.  Each is a full :class:`SortPlan` and inherits the
            per-level strategy dispatch (DESIGN.md §8), so shards can
            e.g. radix-sort their local runs.
    """

    axis: tuple[str, ...]
    d: int
    n_local: int
    n_pad: int
    oversample: int
    pair_align: int
    s_loc: int
    b_t: int
    c_pair: int
    out_cap: int
    dtype_name: str
    num_words: int
    descending: bool
    impl: str
    interpret: bool
    backend: str
    cfg_fingerprint: str
    run_plan: SortPlan
    dealt_plan: SortPlan
    sample_plan: SortPlan
    bucket_plan: SortPlan

    @property
    def n_glob(self) -> int:
        """Global padded element count (n_pad * d)."""
        return self.n_pad * self.d

    @property
    def bytes_per_element(self) -> int:
        """HBM/interconnect bytes per element (key words + payload)."""
        return 4 * (self.num_words + 1)

    @property
    def exchange_elements(self) -> int:
        """Per-device bucket-exchange volume, c_pair-padded (d * c_pair
        elements sent and received in the fixed-shape all_to_all)."""
        return self.d * self.c_pair

    @property
    def collective_elements(self) -> int:
        """Total per-device interconnect elements across the schedule:
        the deal all_to_all (n_pad) + the sample gather (d * s_loc) +
        the bucket exchange (d * c_pair) — cost-model input."""
        return self.n_pad + self.d * self.s_loc + self.exchange_elements

    def signature(self) -> tuple:
        """The cache identity: mesh signature (axis names + D), shard
        shape, dtype+order, oversample/pair_align, resolved backend
        triple, and the requesting config's fingerprint."""
        return (
            "x".join(self.axis),
            self.d,
            self.n_local,
            self.dtype_name,
            self.descending,
            self.oversample,
            self.pair_align,
            self.impl,
            self.interpret,
            self.backend,
            self.cfg_fingerprint,
        )

    def describe(self) -> str:
        """Human-readable summary of the distributed schedule."""
        lines = [
            f"ShardPlan(axis={self.axis}, d={self.d}, "
            f"n_local={self.n_local}->{self.n_pad}, "
            f"dtype={self.dtype_name}"
            f"{' desc' if self.descending else ''}, "
            f"oversample={self.oversample}, c_pair={self.c_pair}, "
            f"out_cap={self.out_cap}, impl={self.impl})"
        ]
        for name in ("run_plan", "dealt_plan", "sample_plan", "bucket_plan"):
            sub: SortPlan = getattr(self, name)
            lines.append(
                f"  {name}: length={sub.length} levels={sub.num_levels} "
                f"strategy={sub.root.strategy}"
            )
        return "\n".join(lines)


@functools.lru_cache(maxsize=256)
def _assemble_shard_plan(
    axis: tuple[str, ...],
    d: int,
    n_local: int,
    dtype_name: str,
    nw: int,
    descending: bool,
    cfg: SortConfig,
    oversample: int,
    pair_align: int,
    impl: str,
    interpret: bool,
    backend: str,
) -> ShardPlan:
    """Memoized shard-plan assembly (resolved backend triple in the
    key, as in :func:`_assemble_plan`): repeated calls return the SAME
    object, so the distributed executor's jit static-arg lookups are
    fast and equal signatures share one executable."""
    g = shard_geometry(n_local, d, oversample, pair_align)
    sub = functools.partial(build_words_plan, num_words=nw, cfg=cfg)
    return ShardPlan(
        axis=axis,
        d=d,
        n_local=n_local,
        n_pad=g.n_pad,
        oversample=oversample,
        pair_align=pair_align,
        s_loc=g.s_loc,
        b_t=g.b_t,
        c_pair=g.c_pair,
        out_cap=g.out_cap,
        dtype_name=dtype_name,
        num_words=nw,
        descending=descending,
        impl=impl,
        interpret=interpret,
        backend=backend,
        cfg_fingerprint=config_fingerprint(cfg),
        run_plan=sub(g.n_pad),
        dealt_plan=sub(g.n_pad),
        sample_plan=sub(d * g.s_loc),
        bucket_plan=sub(d * g.c_pair),
    )


def build_shard_plan(
    axis,
    d: int,
    n_local: int,
    dtype,
    cfg: SortConfig,
    *,
    oversample: int = 8,
    pair_align: int = 8,
) -> ShardPlan:
    """Compute the full static distributed schedule for one signature.

    Pure and deterministic, like :func:`build_plan`: the same
    ``(axis, d, n_local, dtype, cfg, oversample, pair_align)`` produces
    an equal (and identical-object, memoized) plan.  The executor in
    ``core/distributed_sort.py`` derives nothing from it.

    Args:
        axis: mesh axis name (str) or tuple of names; normalized to a
            tuple in the plan.
        d: devices along the sort axis (>= 2).
        n_local: per-shard element count (n_global // d).
        dtype: key dtype (any ``core/key_codec`` dtype; 64-bit needs
            x64 mode).
        cfg: pipeline knobs for the per-phase local sorts
            (``descending`` honored; ``plan`` is NOT consulted here —
            plan selection happens in ``make_sharded_sort``).
        oversample: regular-sampling oversample factor c (power of two
            >= 1; bounds every global bucket at n_pad*(1 + 1/c)).
        pair_align: lane-alignment multiple of the per-pair exchange
            capacity (power of two >= 8).
    Returns:
        A frozen, hashable :class:`ShardPlan`.
    Raises:
        ValueError: naming the offending argument (``oversample``,
            ``pair_align``, ``d``, ``n_local``) — validation happens at
            plan-build time, not as a shape error mid-trace.

    Example:
        >>> from repro.core.plan import build_shard_plan
        >>> from repro.core.sort_config import SortConfig
        >>> p = build_shard_plan("data", 4, 2048, "int32",
        ...                      SortConfig(impl="xla"), oversample=8)
        >>> (p.axis, p.n_pad, p.c_pair % 8, p.out_cap >= p.b_t)
        (('data',), 2048, 0, True)
    """
    import jax.numpy as jnp

    axt = (axis,) if isinstance(axis, str) else tuple(axis)
    codec = codec_for(dtype, cfg.descending)
    impl, interpret, backend = _resolve_backend(cfg)
    return _assemble_shard_plan(
        axt, d, n_local, jnp.dtype(dtype).name, codec.num_words,
        cfg.descending, cfg, oversample, pair_align, impl, interpret,
        backend,
    )


# ----------------------------------------------------------------------
# Serialization: byte-stable dict/JSON round-trip for the plan cache
# ----------------------------------------------------------------------

# v2: LevelPlan grew the per-level strategy fields (strategy /
# radix_bits / merge_run).  Pre-strategy v1 records fail plan_from_dict
# with a ValueError, which the autotune store treats as a clean cache
# miss (re-tune and overwrite) — never a silently misread plan.
_SCHEMA = "sort_plan/v2"


def _node_to_dict(node: LevelPlan | None):
    if node is None:
        return None
    d = dataclasses.asdict(node)
    d["sample_plan"] = _node_to_dict(node.sample_plan)
    d["bucket_plan"] = _node_to_dict(node.bucket_plan)
    return d


def _node_from_dict(d) -> LevelPlan | None:
    if d is None:
        return None
    d = dict(d)
    d["sample_plan"] = _node_from_dict(d.get("sample_plan"))
    d["bucket_plan"] = _node_from_dict(d.get("bucket_plan"))
    return LevelPlan(**d)


def plan_to_dict(plan: SortPlan) -> dict:
    """JSON-serializable representation; inverse of :func:`plan_from_dict`.

    ``plan_from_dict(plan_to_dict(p)) == p`` exactly (tested), which is
    what lets the persistent cache assert a reloaded plan is identical
    to the one it saved.
    """
    d = dataclasses.asdict(plan)
    d["root"] = _node_to_dict(plan.root)
    d["schema"] = _SCHEMA
    return d


def plan_from_dict(d: dict) -> SortPlan:
    """Reconstruct a :class:`SortPlan` saved by :func:`plan_to_dict`.

    Raises:
        ValueError: on a missing/mismatched schema tag.
    """
    d = dict(d)
    schema = d.pop("schema", None)
    if schema != _SCHEMA:
        raise ValueError(f"not a {_SCHEMA} record (schema={schema!r})")
    d["root"] = _node_from_dict(d["root"])
    return SortPlan(**d)


def plan_json(plan: SortPlan) -> str:
    """Canonical JSON encoding (sorted keys) — byte-identical for equal
    plans; the determinism property tests compare these strings."""
    return json.dumps(plan_to_dict(plan), sort_keys=True)


# v1: the initial distributed-schedule record.  The four per-phase
# sub-plans are embedded as full sort_plan/v2 records, so a sort-plan
# schema bump invalidates stored shard plans too (plan_from_dict raises
# and the autotune store treats the record as a clean miss).
_SHARD_SCHEMA = "shard_plan/v1"
_SHARD_SUBPLANS = ("run_plan", "dealt_plan", "sample_plan", "bucket_plan")


def shard_plan_to_dict(plan: ShardPlan) -> dict:
    """JSON-serializable representation; inverse of
    :func:`shard_plan_from_dict` (exact round-trip, tested)."""
    d = dataclasses.asdict(plan)
    d["axis"] = list(plan.axis)
    for name in _SHARD_SUBPLANS:
        d[name] = plan_to_dict(getattr(plan, name))
    d["schema"] = _SHARD_SCHEMA
    return d


def shard_plan_from_dict(d: dict) -> ShardPlan:
    """Reconstruct a :class:`ShardPlan` saved by
    :func:`shard_plan_to_dict`.

    Raises:
        ValueError: on a missing/mismatched schema tag (also raised by
            the embedded per-phase ``plan_from_dict`` calls for stale
            sub-plan schemas).
    """
    d = dict(d)
    schema = d.pop("schema", None)
    if schema != _SHARD_SCHEMA:
        raise ValueError(f"not a {_SHARD_SCHEMA} record (schema={schema!r})")
    d["axis"] = tuple(d["axis"])
    for name in _SHARD_SUBPLANS:
        d[name] = plan_from_dict(d[name])
    return ShardPlan(**d)


def shard_plan_json(plan: ShardPlan) -> str:
    """Canonical JSON encoding of a shard plan (sorted keys)."""
    return json.dumps(shard_plan_to_dict(plan), sort_keys=True)
