"""Sort-plan IR: the static schedule of GPU BUCKET SORT as data.

The paper's deterministic regular sampling makes every quantity of the
multi-level pipeline a *static* function of ``(shape, dtype, config)``:
recursion levels, per-level ``rows x tile`` geometry, ``s_round``,
bucket capacities, pad budgets, kernel block sizes, fusion and
relocation choices.  Nothing is data-dependent — that is the theorem
that lets the whole sort run under XLA's static shapes (DESIGN.md §2).

This module reifies that schedule as a frozen, hashable IR
(:class:`SortPlan` / :class:`LevelPlan`) computed ONCE by
:func:`build_plan` and merely *walked* by the executor in
``core/bucket_sort.py``.  The split buys three things (DESIGN.md §7):

  * the executor's step functions take plan fields instead of
    re-deriving geometry, so one mechanism drives the 1-D, batched,
    segmented, partial (top-k) and distributed entry points;
  * plans are jit static arguments — equal plans hit the same compiled
    executable, so a plan-cache hit means ZERO retraces;
  * plans serialize (:func:`plan_to_dict` / :func:`plan_from_dict`)
    byte-stably, which is what the ``core/autotune.py`` persistent plan
    cache stores and reloads.

``build_plan`` is pure and deterministic: the same
``(length, dtype, cfg, rows)`` produces a byte-identical plan
(property-tested in ``tests/test_plan.py``).  The only environment
inputs are the resolved backend/impl/interpret defaults, which are part
of the plan's identity (and of the autotune cache key).
"""

from __future__ import annotations

import dataclasses
import functools
import hashlib
import json

import jax

from repro.core.key_codec import codec_for
from repro.core.sort_config import SortConfig, next_pow2, round_up

# Static recursion depth guard: the level count shrinks geometrically
# (cap < lp and m*s < lp for s < tile), so real plans are < 8 levels
# deep; hitting this means a degenerate config (e.g. s == tile with
# length > direct_max, where the sample array never shrinks).
_MAX_DEPTH = 64


@dataclasses.dataclass(frozen=True)
class LevelPlan:
    """One node of the static recursion tree (all trace-time ints).

    ``kind == "direct"``: single-tile bitonic sort of each (rows, lp)
    row, lp = next_pow2(length).  ``kind == "bucket"``: one bucket
    round — local tile sort, sample recursion (``sample_plan``),
    splitter partition, relocation into the dense (rows*s_round, cap)
    bucket array, bucket recursion (``bucket_plan``), compaction.

    Attributes:
        kind: "direct" | "bucket".
        rows: row count entering this level.
        length: row length entering this level (pre-padding).
        lp: padded row length (direct: next power of two; bucket:
            rounded up to a tile multiple).
        block_rows: resolved tiles-per-grid-program for the level's
            bitonic sort (None on the xla path) — plan-carried kernel
            geometry, already a power-of-two divisor of the tile count.
        tile / s: the level's tile width T and samples per tile
            (bucket levels only; 0 for direct).
        m: tiles per row (lp // tile).
        s_round: buckets this round (equidistant global splitters + 1).
        cap: static per-bucket capacity — the paper's regular-sampling
            bound round_up(lp/s_round + lp/s, 128) (DESIGN.md §2).
        part_block_rows: resolved block size of the fused
            splitter-partition kernel (None when unfused / xla).
        fuse_sampling / fuse_ranking / relocation: per-level pipeline
            choices (today uniform across levels, copied from cfg).
        strategy: the level's local-sort algorithm ("bitonic" | "radix"
            | "merge") — a PER-LEVEL plan field (DESIGN.md §8); the
            executor dispatches ``ops.sort_tiles`` on it.
        radix_bits / merge_run: strategy knobs carried alongside
            (consulted only by the matching strategy).
        sample_plan: step-4 recursion on the (rows, m*s) sample array.
        bucket_plan: step-9 recursion on the (rows*s_round, cap)
            bucket rows.
    """

    kind: str
    rows: int
    length: int
    lp: int
    block_rows: int | None
    tile: int = 0
    s: int = 0
    m: int = 0
    s_round: int = 0
    cap: int = 0
    part_block_rows: int | None = None
    fuse_sampling: bool = False
    fuse_ranking: bool = False
    relocation: str = "gather"
    strategy: str = "bitonic"
    radix_bits: int = 4
    merge_run: int = 512
    sample_plan: "LevelPlan | None" = None
    bucket_plan: "LevelPlan | None" = None


@dataclasses.dataclass(frozen=True)
class SortPlan:
    """The full static schedule of one sort signature.

    Frozen and hashable: used as a jit static argument, so two calls
    carrying equal plans share one compiled executable.

    Attributes:
        rows: entry row count (1 for the 1-D API, B for batched).
        length: entry row length L.
        dtype_name: canonical key dtype name (``jnp.dtype(...).name``).
        num_words: uint32 key words per element (codec).
        descending: order baked into the key codec.
        impl: resolved implementation ("pallas" | "xla").
        interpret: resolved Pallas interpret mode.
        backend: jax.default_backend() at build time (cache key part).
        rows_padded: rows after batch row-padding (== rows unless the
            batched pallas path pads to a cfg.row_pad multiple).
        cfg_fingerprint: stable hash of the generating config (every
            field except ``plan`` — see :func:`config_fingerprint`).
        root: the level tree the executor walks.
    """

    rows: int
    length: int
    dtype_name: str
    num_words: int
    descending: bool
    impl: str
    interpret: bool
    backend: str
    rows_padded: int
    cfg_fingerprint: str
    root: LevelPlan

    @property
    def num_levels(self) -> int:
        """Bucket rounds on the main (bucket_plan) spine."""
        n, node = 0, self.root
        while node is not None and node.kind == "bucket":
            n += 1
            node = node.bucket_plan
        return n

    def signature(self) -> tuple:
        """The cache identity: (shape, dtype, backend, cfg-fingerprint)."""
        return (
            self.rows,
            self.length,
            self.dtype_name,
            self.descending,
            self.impl,
            self.interpret,
            self.backend,
            self.cfg_fingerprint,
        )

    def describe(self) -> str:
        """Human-readable one-plan summary (levels and geometry)."""
        lines = [
            f"SortPlan(rows={self.rows}->{self.rows_padded}, "
            f"length={self.length}, dtype={self.dtype_name}"
            f"{' desc' if self.descending else ''}, impl={self.impl}, "
            f"levels={self.num_levels})"
        ]
        node, depth = self.root, 0
        while node is not None:
            if node.kind == "direct":
                lines.append(
                    f"  L{depth}: direct rows={node.rows} lp={node.lp} "
                    f"block_rows={node.block_rows} strategy={node.strategy}"
                )
                break
            lines.append(
                f"  L{depth}: bucket rows={node.rows} lp={node.lp} "
                f"tile={node.tile} s={node.s} m={node.m} "
                f"s_round={node.s_round} cap={node.cap} "
                f"block_rows={node.block_rows} reloc={node.relocation} "
                f"strategy={node.strategy}"
            )
            node = node.bucket_plan
            depth += 1
        return "\n".join(lines)


def config_fingerprint(cfg: SortConfig) -> str:
    """Stable hash of every SortConfig field except ``plan`` itself.

    The ``plan`` field selects HOW a plan is obtained (default /
    autotune / file); it must not perturb the identity of the plans the
    cache is keyed by, or a cached plan could never match the config
    that requests it.
    """
    d = dataclasses.asdict(cfg)
    d.pop("plan", None)
    blob = json.dumps(d, sort_keys=True, default=str)
    return hashlib.sha256(blob.encode()).hexdigest()[:16]


def _resolve_backend(cfg: SortConfig) -> tuple[str, bool, str]:
    """(impl, interpret, backend) with the cfg Nones resolved."""
    from repro.kernels import ops  # local import: ops imports core.key_codec

    impl = cfg.impl or ops.default_impl()
    interpret = (
        ops.default_interpret() if cfg.interpret is None else cfg.interpret
    )
    return impl, interpret, jax.default_backend()


def _sort_block_rows(
    impl: str, tiles: int, t: int, cfg_block_rows: int | None, nw: int
) -> int | None:
    from repro.kernels import bitonic

    if impl != "pallas":
        return None
    return bitonic.effective_block_rows(tiles, t, cfg_block_rows, num_words=nw)


def _build_node(
    rows: int, length: int, cfg: SortConfig, impl: str, nw: int, depth: int
) -> LevelPlan:
    if depth > _MAX_DEPTH:
        raise ValueError(
            "sort-plan recursion exceeded depth "
            f"{_MAX_DEPTH} at (rows={rows}, length={length}); degenerate "
            "config (s == tile with length > direct_max never shrinks "
            "the sample array)"
        )
    if length <= cfg.direct_max:
        lp = next_pow2(length)
        return LevelPlan(
            kind="direct",
            rows=rows,
            length=length,
            lp=lp,
            block_rows=_sort_block_rows(impl, rows, lp, cfg.block_rows, nw),
            strategy=cfg.strategy,
            radix_bits=cfg.radix_bits,
            merge_run=cfg.merge_run,
        )

    t, sper = cfg.tile, cfg.s
    lp = round_up(length, t)
    m = lp // t
    # Step 5: s_round - 1 equidistant global splitters (s_round buckets).
    s_round = min(max(next_pow2(-(-2 * lp // t)), 2), sper)
    # The paper's guaranteed capacity (DESIGN.md §2), lane-aligned.
    cap = round_up(lp // s_round + lp // sper, 128)
    part_block_rows = None
    if impl == "pallas" and cfg.fuse_ranking:
        from repro.kernels import splitter

        part_block_rows = splitter.partition_block_rows(
            rows * m, t, s_round - 1, num_words=nw
        )
    return LevelPlan(
        kind="bucket",
        rows=rows,
        length=length,
        lp=lp,
        block_rows=_sort_block_rows(impl, rows * m, t, cfg.block_rows, nw),
        tile=t,
        s=sper,
        m=m,
        s_round=s_round,
        cap=cap,
        part_block_rows=part_block_rows,
        fuse_sampling=cfg.fuse_sampling,
        fuse_ranking=cfg.fuse_ranking,
        relocation=cfg.relocation,
        strategy=cfg.strategy,
        radix_bits=cfg.radix_bits,
        merge_run=cfg.merge_run,
        sample_plan=_build_node(rows, m * sper, cfg, impl, nw, depth + 1),
        bucket_plan=_build_node(
            rows * s_round, cap, cfg, impl, nw, depth + 1
        ),
    )


@functools.lru_cache(maxsize=512)
def _assemble_plan(
    rows: int,
    length: int,
    dtype_name: str,
    nw: int,
    descending: bool,
    cfg: SortConfig,
    pad_rows: bool,
    impl: str,
    interpret: bool,
    backend: str,
) -> SortPlan:
    """Memoized plan assembly: the cache key includes the RESOLVED
    backend triple, so a changed env/backend can never serve a stale
    plan, while repeated calls return the SAME object (fast jit static
    lookups)."""
    rows_padded = rows
    if pad_rows and impl == "pallas" and cfg.row_pad > 1 and rows > 0:
        rows_padded = round_up(rows, cfg.row_pad)
    return SortPlan(
        rows=rows,
        length=length,
        dtype_name=dtype_name,
        num_words=nw,
        descending=descending,
        impl=impl,
        interpret=interpret,
        backend=backend,
        rows_padded=rows_padded,
        cfg_fingerprint=config_fingerprint(cfg),
        root=_build_node(max(rows_padded, 1), length, cfg, impl, nw, 0),
    )


def build_plan(
    length: int,
    dtype,
    cfg: SortConfig,
    *,
    rows: int = 1,
    pad_rows: bool = False,
) -> SortPlan:
    """Compute the full static schedule for one sort signature.

    Pure and deterministic: equal inputs produce equal (byte-identical
    once serialized) plans.  Called once per signature (memoized); the
    executor in ``core/bucket_sort.py`` only walks the result.

    Args:
        length: row length L (the 1-D array length, or the row width of
            the batched/segmented packed array).
        dtype: key dtype (any ``core/key_codec`` dtype).
        cfg: pipeline knobs; ``cfg.descending`` is baked into the plan
            identity, ``cfg.plan`` is NOT (it selects how plans are
            obtained, see :func:`config_fingerprint`).
        rows: entry row count (1 for the 1-D API, B for batched).
        pad_rows: apply the batched-path row padding to a multiple of
            ``cfg.row_pad`` (DESIGN.md §5) — the batched/segmented
            entry points pass True, the 1-D path False.
    Returns:
        A frozen :class:`SortPlan`.

    Example:
        >>> from repro.core.plan import build_plan
        >>> from repro.core.sort_config import SortConfig
        >>> p = build_plan(100_000, "int32", SortConfig(impl="xla"))
        >>> (p.length, p.root.kind, p.num_levels >= 1)
        (100000, 'bucket', True)
    """
    import jax.numpy as jnp

    codec = codec_for(dtype, cfg.descending)
    impl, interpret, backend = _resolve_backend(cfg)
    return _assemble_plan(
        rows, length, jnp.dtype(dtype).name, codec.num_words,
        cfg.descending, cfg, pad_rows, impl, interpret, backend,
    )


def build_words_plan(
    length: int,
    num_words: int,
    cfg: SortConfig,
    *,
    rows: int = 1,
    pad_rows: bool = False,
) -> SortPlan:
    """Plan for callers already holding CANONICAL uint32 key words
    (``distributed_sort.sorted_shard``, the recursion shims): the
    canonical domain is always ascending, so there is no dtype/codec —
    only the word count matters for geometry."""
    impl, interpret, backend = _resolve_backend(cfg)
    return _assemble_plan(
        rows, length, f"uint32x{num_words}", num_words, False, cfg,
        pad_rows, impl, interpret, backend,
    )


# ----------------------------------------------------------------------
# Partial-sort (top-k) plan: the one-bucket-round schedule
# ----------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TopkPlan:
    """Static schedule of the partial sort (one bucket round, steps 1-7
    + candidate pack + candidate sort — ``core/partial_sort.py``).

    Attributes:
        rows: batch rows (1 for the 1-D entry).
        length: scores per row (n / vocab).
        k: requested top-k.
        lp: length padded to a tile multiple.
        m: tiles per row.
        tile / s: tile width and samples per tile.
        cap: the bucket-capacity bound round_up(2*lp/s, 128) the
            threshold argument relies on.
        ccap: static candidate-buffer width round_up(min(k+cap, lp), 128).
        block_rows: resolved tile-sort block size (None on xla).
        raw_block_rows: the unresolved cfg knob, carried as the UPPER
            BOUND for the small sample/candidate sorts (whose padded
            widths the kernels clamp against).
        direct_max: lengths up to this take the direct single-tile path.
        strategy / radix_bits / merge_run: the local-sort strategy for
            the tile/candidate sorts, copied from the cfg (DESIGN.md
            §8; the candidate packs preserve the payload invariant the
            non-bitonic strategies rely on).
        impl / interpret / backend: resolved as in :class:`SortPlan`.
    """

    rows: int
    length: int
    k: int
    lp: int
    m: int
    tile: int
    s: int
    cap: int
    ccap: int
    block_rows: int | None
    raw_block_rows: int | None
    direct_max: int
    impl: str
    interpret: bool
    backend: str
    strategy: str = "bitonic"
    radix_bits: int = 4
    merge_run: int = 512


@functools.lru_cache(maxsize=512)
def _assemble_topk_plan(
    length: int, k: int, nw: int, cfg: SortConfig, rows: int,
    impl: str, interpret: bool, backend: str,
) -> TopkPlan:
    """Memoized topk-plan assembly; like :func:`_assemble_plan`, the
    RESOLVED backend triple is part of the cache key so a changed
    env/backend can never serve a stale plan."""
    t, sper = cfg.tile, cfg.s
    lp = round_up(length, t)
    m = lp // t
    cap = round_up(2 * lp // sper, 128)
    ccap = round_up(min(k + cap, lp), 128)
    return TopkPlan(
        rows=rows,
        length=length,
        k=k,
        lp=lp,
        m=m,
        tile=t,
        s=sper,
        cap=cap,
        ccap=ccap,
        block_rows=_sort_block_rows(
            impl, max(rows, 1) * m, t, cfg.block_rows, nw
        ),
        raw_block_rows=cfg.block_rows,
        direct_max=cfg.direct_max,
        impl=impl,
        interpret=interpret,
        backend=backend,
        strategy=cfg.strategy,
        radix_bits=cfg.radix_bits,
        merge_run=cfg.merge_run,
    )


def build_topk_plan(
    length: int, k: int, dtype, cfg: SortConfig, *, rows: int = 1
) -> TopkPlan:
    """Static schedule for :func:`repro.core.partial_sort.topk`.

    Same builder conventions as :func:`build_plan` (pure,
    deterministic, backend-resolved, memoized).  Lengths <=
    cfg.direct_max take the direct path and never consult the bucket
    fields.
    """
    codec = codec_for(dtype, descending=True)
    impl, interpret, backend = _resolve_backend(cfg)
    return _assemble_topk_plan(
        length, k, codec.num_words, cfg, rows, impl, interpret, backend
    )


# ----------------------------------------------------------------------
# Serialization: byte-stable dict/JSON round-trip for the plan cache
# ----------------------------------------------------------------------

# v2: LevelPlan grew the per-level strategy fields (strategy /
# radix_bits / merge_run).  Pre-strategy v1 records fail plan_from_dict
# with a ValueError, which the autotune store treats as a clean cache
# miss (re-tune and overwrite) — never a silently misread plan.
_SCHEMA = "sort_plan/v2"


def _node_to_dict(node: LevelPlan | None):
    if node is None:
        return None
    d = dataclasses.asdict(node)
    d["sample_plan"] = _node_to_dict(node.sample_plan)
    d["bucket_plan"] = _node_to_dict(node.bucket_plan)
    return d


def _node_from_dict(d) -> LevelPlan | None:
    if d is None:
        return None
    d = dict(d)
    d["sample_plan"] = _node_from_dict(d.get("sample_plan"))
    d["bucket_plan"] = _node_from_dict(d.get("bucket_plan"))
    return LevelPlan(**d)


def plan_to_dict(plan: SortPlan) -> dict:
    """JSON-serializable representation; inverse of :func:`plan_from_dict`.

    ``plan_from_dict(plan_to_dict(p)) == p`` exactly (tested), which is
    what lets the persistent cache assert a reloaded plan is identical
    to the one it saved.
    """
    d = dataclasses.asdict(plan)
    d["root"] = _node_to_dict(plan.root)
    d["schema"] = _SCHEMA
    return d


def plan_from_dict(d: dict) -> SortPlan:
    """Reconstruct a :class:`SortPlan` saved by :func:`plan_to_dict`.

    Raises:
        ValueError: on a missing/mismatched schema tag.
    """
    d = dict(d)
    schema = d.pop("schema", None)
    if schema != _SCHEMA:
        raise ValueError(f"not a {_SCHEMA} record (schema={schema!r})")
    d["root"] = _node_from_dict(d["root"])
    return SortPlan(**d)


def plan_json(plan: SortPlan) -> str:
    """Canonical JSON encoding (sorted keys) — byte-identical for equal
    plans; the determinism property tests compare these strings."""
    return json.dumps(plan_to_dict(plan), sort_keys=True)
