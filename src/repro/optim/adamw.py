"""AdamW (pure JAX): dtype-configurable moments for large-model memory.

jamba-1.5-large (398B params) needs bf16 moments to fit the optimizer
state in 16 GB/chip at 256 chips (DESIGN.md §5); updates are computed
in f32 regardless of the storage dtype.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config import OptimizerConfig


def adamw_init(params, cfg: OptimizerConfig):
    mdt = jnp.dtype(cfg.moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, mdt)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def clip_by_global_norm(grads, max_norm: float):
    gsq = sum(
        jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(grads)
    )
    gnorm = jnp.sqrt(gsq)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gnorm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), gnorm


def adamw_update(params, grads, state, cfg: OptimizerConfig, lr):
    """Returns (new_params, new_state).  lr: scalar (scheduled outside)."""
    step = state["step"] + 1
    b1, b2 = cfg.beta1, cfg.beta2
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)
    mdt = jnp.dtype(cfg.moment_dtype)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        mf = m.astype(jnp.float32) * b1 + gf * (1 - b1)
        vf = v.astype(jnp.float32) * b2 + gf * gf * (1 - b2)
        mhat = mf / c1
        vhat = vf / c2
        pf = p.astype(jnp.float32)
        pf = pf - lr * (mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * pf)
        return pf.astype(p.dtype), mf.astype(mdt), vf.astype(mdt)

    out = jax.tree.map(upd, params, grads, state["m"], state["v"])
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return new_params, {"m": new_m, "v": new_v, "step": step}
