"""Int8 gradient compression with error feedback (DP all-reduce trick).

For bandwidth-bound data-parallel training: quantize each gradient leaf
to int8 with a per-leaf f32 scale before the cross-replica all-reduce,
keep the quantization residual locally and add it back into the next
step's gradient (error feedback, Seide et al. 2014 / Karimireddy et al.
2019).  4x fewer bytes over the data axis; unbiased-in-the-limit via the
residual.  Used by the shard_map DP path in runtime/train driver when
ParallelConfig.compress_grads is set.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def compress_grads_int8(grads, residual=None):
    """grads -> (q_int8 tree, scales tree, new_residual tree)."""

    def comp(g, r):
        gf = g.astype(jnp.float32) + (r if r is not None else 0.0)
        scale = jnp.max(jnp.abs(gf)) / 127.0 + 1e-12
        q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
        res = gf - q.astype(jnp.float32) * scale
        return q, scale, res

    if residual is None:
        residual = jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)
    out = jax.tree.map(comp, grads, residual)
    istup = lambda x: isinstance(x, tuple)
    q = jax.tree.map(lambda t: t[0], out, is_leaf=istup)
    s = jax.tree.map(lambda t: t[1], out, is_leaf=istup)
    r = jax.tree.map(lambda t: t[2], out, is_leaf=istup)
    return q, s, r


def decompress_grads_int8(q, scales):
    return jax.tree.map(
        lambda qq, ss: qq.astype(jnp.float32) * ss, q, scales
    )


def allreduce_compressed(grads, axis_name, residual=None):
    """shard_map body helper: int8 psum with error feedback.

    Scales are psum-maxed first so all replicas dequantize identically.
    """
    def comp(g, r):
        gf = g.astype(jnp.float32) + r
        local_scale = jnp.max(jnp.abs(gf)) / 127.0 + 1e-12
        scale = jax.lax.pmax(local_scale, axis_name)
        q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
        res = gf - q.astype(jnp.float32) * scale
        # int8 psum accumulates in int32 to avoid overflow
        tot = jax.lax.psum(q.astype(jnp.int32), axis_name)
        n = jax.lax.psum(1, axis_name)
        return tot.astype(jnp.float32) * scale / n, res

    if residual is None:
        residual = jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)
    out = jax.tree.map(comp, grads, residual)
    istup = lambda x: isinstance(x, tuple)
    mean = jax.tree.map(lambda t: t[0], out, is_leaf=istup)
    res = jax.tree.map(lambda t: t[1], out, is_leaf=istup)
    return mean, res
