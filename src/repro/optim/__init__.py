from repro.optim.adamw import adamw_init, adamw_update, clip_by_global_norm
from repro.optim.schedule import cosine_warmup
from repro.optim.compress import compress_grads_int8, decompress_grads_int8

__all__ = [
    "adamw_init",
    "adamw_update",
    "clip_by_global_norm",
    "cosine_warmup",
    "compress_grads_int8",
    "decompress_grads_int8",
]
