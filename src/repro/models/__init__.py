# Model zoo: one periodic-pattern decoder LM covering dense GQA / MLA /
# MoE (sample-sort dispatch) / Mamba-2 SSD / hybrid, plus an enc-dec
# backbone (whisper) and stub modality frontends (audio/vision).
