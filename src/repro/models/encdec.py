"""Encoder-decoder backbone (whisper-large-v3 shape).

The audio conv frontend is a STUB per the assignment: inputs are
precomputed frame embeddings (B, F, d_model).  Encoder = bidirectional
self-attention + GELU MLP; decoder = causal self-attention +
cross-attention + GELU MLP; layernorm throughout.  Positions are
sinusoidal (whisper's encoder convention; decoder's learned table is
approximated sinusoidally — backbone-fidelity note in DESIGN.md).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models import attention as attn
from repro.models import layers as L
from repro.models.meta import ParamMeta
from repro.models.transformer import (
    _layer_loop,
    _layer_loop_cache,
    _remat,
    _stack_period,
    chunked_ce,
)
from repro.sharding import constrain


def sinusoid(positions, d: int):
    """(S,) -> (S, d) sinusoidal embedding (whisper convention)."""
    half = d // 2
    freqs = jnp.exp(
        -jnp.log(10000.0) * jnp.arange(half, dtype=jnp.float32) / (half - 1)
    )
    ang = positions[:, None].astype(jnp.float32) * freqs[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def _enc_slot(cfg):
    return {
        "ln": L.norm_template(cfg),
        "attn": attn.gqa_template(cfg),
        "ln2": L.norm_template(cfg),
        "mlp": L.mlp_template(cfg),
    }


def _dec_slot(cfg):
    return {
        "ln": L.norm_template(cfg),
        "attn": attn.gqa_template(cfg),
        "ln_x": L.norm_template(cfg),
        "xattn": attn.cross_template(cfg),
        "ln2": L.norm_template(cfg),
        "mlp": L.mlp_template(cfg),
    }


def encdec_template(cfg: ModelConfig):
    assert cfg.n_encoder_layers > 0
    return {
        "embed": L.embed_template(cfg),
        "enc_period": _stack_period(_enc_slot(cfg), cfg.n_encoder_layers),
        "enc_final_norm": L.norm_template(cfg),
        "period": _stack_period(_dec_slot(cfg), cfg.n_layers),
        "final_norm": L.norm_template(cfg),
    }


def encode(params, frames, cfg: ModelConfig):
    """frames: (B,F,d) stub embeddings -> encoder memory (B,F,d)."""
    bsz, f, d = frames.shape
    x = frames.astype(cfg.dtype) + sinusoid(jnp.arange(f), d)[None].astype(cfg.dtype)
    positions = jnp.arange(f)[None, :]

    def fn(x, pp):
        h = attn.gqa_forward(
            pp["attn"], L.norm_apply(pp["ln"], x, cfg), cfg, positions, causal=False
        )
        x = x + h
        x = x + L.mlp_apply(pp["mlp"], L.norm_apply(pp["ln2"], x, cfg), cfg)
        return constrain(x, "batch", "seq", "embed"), None

    x = _layer_loop(cfg, _remat(cfg, fn), x, params["enc_period"])
    return L.norm_apply(params["enc_final_norm"], x, cfg)


def _dec_block(pp, x, memory, cfg, positions):
    x = x + attn.gqa_forward(
        pp["attn"], L.norm_apply(pp["ln"], x, cfg), cfg, positions, causal=True
    )
    x = x + attn.cross_forward(
        pp["xattn"], L.norm_apply(pp["ln_x"], x, cfg), memory, cfg
    )
    x = x + L.mlp_apply(pp["mlp"], L.norm_apply(pp["ln2"], x, cfg), cfg)
    return constrain(x, "batch", "seq", "embed")


def encdec_loss(params, batch, cfg: ModelConfig):
    memory = encode(params, batch["enc_frames"], cfg)
    tokens, targets = batch["tokens"], batch["targets"]
    bsz, s = tokens.shape
    x = L.embed_apply(params["embed"], tokens, cfg)
    x = x + sinusoid(jnp.arange(s), cfg.d_model)[None].astype(x.dtype)
    positions = jnp.arange(s)[None, :]

    def fn(x, pp):
        return _dec_block(pp, x, memory, cfg, positions), None

    x = _layer_loop(cfg, _remat(cfg, fn), x, params["period"])
    x = L.norm_apply(params["final_norm"], x, cfg)
    b, s, _ = x.shape
    return chunked_ce(params, x, targets, cfg) / (b * s)


# ------------------------------------------------------------- serving
def init_cache(cfg: ModelConfig, batch: int, cache_len: int):
    dt = jnp.dtype(cfg.dtype)
    k, dh, f = cfg.n_kv_heads, cfg.dh, cfg.frontend_len or cfg.encoder_positions
    ent = {
        "k": jnp.zeros((batch, cache_len, k, dh), dt),
        "v": jnp.zeros((batch, cache_len, k, dh), dt),
        "xk": jnp.zeros((batch, f, k, dh), dt),
        "xv": jnp.zeros((batch, f, k, dh), dt),
    }
    return jax.tree.map(lambda x: jnp.broadcast_to(x, (cfg.n_layers,) + x.shape), ent)


def cache_axes(cfg: ModelConfig):
    return {
        "k": ("layers", "batch", "kv_seq", "kv_heads", "head_dim"),
        "v": ("layers", "batch", "kv_seq", "kv_heads", "head_dim"),
        "xk": ("layers", "batch", None, "kv_heads", "head_dim"),
        "xv": ("layers", "batch", None, "kv_heads", "head_dim"),
    }


def prefill(params, batch, cfg: ModelConfig, cache_len: int):
    """Encode + decoder prefill.  Returns (last logits (B,V), caches)."""
    memory = encode(params, batch["enc_frames"], cfg)
    tokens = batch["tokens"]
    bsz, s = tokens.shape
    x = L.embed_apply(params["embed"], tokens, cfg)
    x = x + sinusoid(jnp.arange(s), cfg.d_model)[None].astype(x.dtype)
    positions = jnp.arange(s)[None, :]

    def fn(x, pp):
        h, cache = attn.gqa_prefill(
            pp["attn"], L.norm_apply(pp["ln"], x, cfg), cfg, positions, cache_len
        )
        x = x + h
        x = x + attn.cross_forward(
            pp["xattn"], L.norm_apply(pp["ln_x"], x, cfg), memory, cfg
        )
        x = x + L.mlp_apply(pp["mlp"], L.norm_apply(pp["ln2"], x, cfg), cfg)
        mem = memory.astype(cfg.dtype)
        xk = jnp.einsum("bsd,dhk->bshk", mem, pp["xattn"]["wk"].astype(cfg.dtype))
        xv = jnp.einsum("bsd,dhk->bshk", mem, pp["xattn"]["wv"].astype(cfg.dtype))
        if "bk" in pp["xattn"]:
            xk = xk + pp["xattn"]["bk"].astype(cfg.dtype)
            xv = xv + pp["xattn"]["bv"].astype(cfg.dtype)
        cache = dict(cache, xk=xk, xv=xv)
        return x, cache

    x, caches = _layer_loop_cache(cfg, fn, x, params["period"], None)
    x = L.norm_apply(params["final_norm"], x, cfg)
    logits = L.unembed_apply(params["embed"], x[:, -1:, :], cfg)[:, 0, :]
    return logits, caches


def decode_step(params, token, caches, pos, cfg: ModelConfig):
    x = L.embed_apply(params["embed"], token, cfg)
    x = x + sinusoid(pos[None], cfg.d_model)[None].astype(x.dtype)

    def fn(x, inp):
        pp, cache = inp
        h, new = attn.gqa_decode(
            pp["attn"], L.norm_apply(pp["ln"], x, cfg), cfg,
            {"k": cache["k"], "v": cache["v"]}, pos,
        )
        x = x + h
        # cross attention against cached memory projections
        xc = L.norm_apply(pp["ln_x"], x, cfg).astype(cfg.dtype)
        q = jnp.einsum("bsd,dhk->bshk", xc, pp["xattn"]["wq"].astype(cfg.dtype))
        if "bq" in pp["xattn"]:
            q = q + pp["xattn"]["bq"].astype(cfg.dtype)
        kh = cache["xk"].shape[2]
        g = q.shape[2] // kh
        b = q.shape[0]
        qg = q.reshape(b, 1, kh, g, cfg.dh)
        s = jnp.einsum(
            "bqkgd,bskd->bkgqs", qg, cache["xk"],
            preferred_element_type=jnp.float32,
        ) * (cfg.dh ** -0.5)
        w = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum(
            "bkgqs,bskd->bkgqd", w.astype(cache["xv"].dtype), cache["xv"],
            preferred_element_type=jnp.float32,
        ).transpose(0, 3, 1, 2, 4).reshape(b, 1, kh * g, cfg.dh)
        x = x + jnp.einsum(
            "bshk,hkd->bsd", o.astype(cfg.dtype), pp["xattn"]["wo"].astype(cfg.dtype)
        )
        x = x + L.mlp_apply(pp["mlp"], L.norm_apply(pp["ln2"], x, cfg), cfg)
        return x, dict(new, xk=cache["xk"], xv=cache["xv"])

    x, new_caches = _layer_loop_cache(cfg, fn, x, params["period"], caches)
    x = L.norm_apply(params["final_norm"], x, cfg)
    logits = L.unembed_apply(params["embed"], x, cfg)[:, 0, :]
    return logits, new_caches
