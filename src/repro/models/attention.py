"""Attention mixers: GQA (chunked/flash-style + decode) and MLA.

Chunked attention scans over query and key blocks with an online
softmax (f32 stats), so prefill_32k activations stay bounded without a
hardware kernel; block size = cfg.attn_chunk.  Causally-masked blocks
above the diagonal are still computed (static shapes) — the roofline
accounts for this (MODEL_FLOPS ratio) and the Pallas flash kernel is
the corresponding hillclimb on real TPU.

MLA (MiniCPM3 / DeepSeek-V2): low-rank Q and KV compression with a
decoupled RoPE channel.  Decode uses the ABSORBED form (scores against
the compressed c_kv cache), which is what makes the MLA cache small.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models.layers import apply_rope
from repro.models.meta import ParamMeta
from repro.sharding import constrain


# ------------------------------------------------------------------ GQA
def gqa_template(cfg: ModelConfig):
    d, h, k, dh, pd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.dh, cfg.param_dtype
    t = {
        "wq": ParamMeta((d, h, dh), ("embed", "heads", "head_dim"), pd),
        "wk": ParamMeta((d, k, dh), ("embed", "kv_heads", "head_dim"), pd),
        "wv": ParamMeta((d, k, dh), ("embed", "kv_heads", "head_dim"), pd),
        "wo": ParamMeta((h, dh, d), ("heads", "head_dim", "embed"), pd),
    }
    if cfg.attn_bias:
        t["bq"] = ParamMeta((h, dh), ("heads", "head_dim"), pd, "zeros")
        t["bk"] = ParamMeta((k, dh), ("kv_heads", "head_dim"), pd, "zeros")
        t["bv"] = ParamMeta((k, dh), ("kv_heads", "head_dim"), pd, "zeros")
    return t


def _qkv(p, x, cfg: ModelConfig):
    x = x.astype(cfg.dtype)
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(cfg.dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(cfg.dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(cfg.dtype))
    if "bq" in p:
        q = q + p["bq"].astype(cfg.dtype)
        k = k + p["bk"].astype(cfg.dtype)
        v = v + p["bv"].astype(cfg.dtype)
    # "qk_seq" gives sequence-TP attention when head counts don't
    # divide the model axis (see sharding.default_rules).
    q = constrain(q, "batch", "qk_seq", "heads", "head_dim")
    k = constrain(k, "batch", "qk_seq", "kv_heads", "head_dim")
    v = constrain(v, "batch", "qk_seq", "kv_heads", "head_dim")
    return q, k, v


def chunked_attention(q, k, v, *, chunk: int, causal: bool, q_offset=0,
                      unroll: bool = False):
    """Online-softmax attention.  q: (B,Sq,H,D); k,v: (B,Sk,K,D), H=K*G.

    Scans over key blocks (and maps over query blocks) with f32 running
    max / denominator — memory O(Sq * chunk) instead of O(Sq * Sk).
    unroll=True replaces the loops with straight-line code (identical
    math): used by the dry-run probes because XLA cost_analysis counts
    while-loop bodies once.
    """
    b, sq0, h, d = q.shape
    sk0, kh = k.shape[1], k.shape[2]
    dv = v.shape[-1]  # MLA: value head dim may differ from qk head dim
    g = h // kh
    cq = min(chunk, sq0)
    ck = min(chunk, sk0)
    # pad both sequence dims to chunk multiples; padded keys are masked,
    # padded query rows are sliced off the output.
    sq = -(-sq0 // cq) * cq
    sk = -(-sk0 // ck) * ck
    if sq > sq0:
        q = jnp.pad(q, ((0, 0), (0, sq - sq0), (0, 0), (0, 0)))
    if sk > sk0:
        k = jnp.pad(k, ((0, 0), (0, sk - sk0), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, sk - sk0), (0, 0), (0, 0)))
    nq, nk = sq // cq, sk // ck
    scale = d ** -0.5

    qb = q.reshape(b, nq, cq, kh, g, d).transpose(1, 0, 2, 3, 4, 5)
    kb = k.reshape(b, nk, ck, kh, d).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(b, nk, ck, kh, dv).transpose(1, 0, 2, 3, 4)

    def q_block(qi, qc):
        # qc: (B, cq, K, G, D)
        def kv_block(carry, inp):
            m, l, acc = carry
            kj, kc, vc = inp
            s = jnp.einsum(
                "bqkgd,bskd->bkgqs", qc, kc, preferred_element_type=jnp.float32
            ) * scale  # (B,K,G,cq,ck)
            kpos = kj * ck + jnp.arange(ck)
            if causal:
                qpos = q_offset + qi * cq + jnp.arange(cq)
                mask = (kpos[None, :] <= qpos[:, None]) & (kpos < sk0)[None, :]
            else:
                mask = jnp.broadcast_to((kpos < sk0)[None, :], (cq, ck))
            s = jnp.where(mask[None, None, None], s, -jnp.inf)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bkgqs,bskd->bkgqd", p.astype(vc.dtype), vc,
                preferred_element_type=jnp.float32,
            )
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, kh, g, cq), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((b, kh, g, cq), jnp.float32)
        a0 = jnp.zeros((b, kh, g, cq, dv), jnp.float32)
        if unroll:
            carry = (m0, l0, a0)
            for kj in range(nk):
                carry, _ = kv_block(carry, (jnp.int32(kj), kb[kj], vb[kj]))
            m, l, acc = carry
        else:
            (m, l, acc), _ = jax.lax.scan(
                kv_block, (m0, l0, a0), (jnp.arange(nk), kb, vb)
            )
        out = acc / jnp.maximum(l, 1e-30)[..., None]  # (B,K,G,cq,Dv)
        return out.transpose(0, 3, 1, 2, 4).reshape(b, cq, h, dv)

    if unroll:
        outs = jnp.stack([q_block(jnp.int32(i), qb[i]) for i in range(nq)])
    else:
        outs = jax.lax.map(lambda args: q_block(*args), (jnp.arange(nq), qb))
    out = outs.transpose(1, 0, 2, 3, 4).reshape(b, sq, h, dv)
    return out[:, :sq0].astype(q.dtype)


def gqa_forward(p, x, cfg: ModelConfig, positions, causal=True):
    """Full-sequence self-attention (train / encoder)."""
    q, k, v = _qkv(p, x, cfg)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    out = chunked_attention(q, k, v, chunk=cfg.attn_chunk, causal=causal,
                            unroll=not cfg.scan_layers)
    out = jnp.einsum("bshk,hkd->bsd", out.astype(cfg.dtype), p["wo"].astype(cfg.dtype))
    return constrain(out, "batch", "seq", "embed")


def gqa_prefill(p, x, cfg: ModelConfig, positions, cache_len: int):
    """Causal forward that also returns a (padded) KV cache entry."""
    q, k, v = _qkv(p, x, cfg)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    out = chunked_attention(q, k, v, chunk=cfg.attn_chunk, causal=True,
                            unroll=not cfg.scan_layers)
    out = jnp.einsum("bshk,hkd->bsd", out.astype(cfg.dtype), p["wo"].astype(cfg.dtype))
    b, s, kh, dh = k.shape
    pad = cache_len - s
    cache = {
        "k": jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0))),
        "v": jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0))),
    }
    cache = {kk: constrain(vv, "batch", "kv_seq", "kv_heads", "head_dim")
             for kk, vv in cache.items()}
    return constrain(out, "batch", "seq", "embed"), cache


def gqa_decode(p, x, cfg: ModelConfig, cache, pos):
    """One-token decode.  x: (B,1,d); cache k/v: (B,L,K,Dh); pos: scalar."""
    q, k, v = _qkv(p, x, cfg)
    q = apply_rope(q, pos[None], cfg.rope_theta)  # positions (1,) broadcasts
    k = apply_rope(k, pos[None], cfg.rope_theta)
    ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k.astype(cache["k"].dtype), pos, axis=1)
    cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v.astype(cache["v"].dtype), pos, axis=1)
    ck = constrain(ck, "batch", "kv_seq", "kv_heads", "head_dim")
    cv = constrain(cv, "batch", "kv_seq", "kv_heads", "head_dim")
    b, l, kh, dh = ck.shape
    g = q.shape[2] // kh
    qg = q.reshape(b, 1, kh, g, dh)
    s = jnp.einsum(
        "bqkgd,bskd->bkgqs", qg, ck, preferred_element_type=jnp.float32
    ) * (dh ** -0.5)
    mask = jnp.arange(l)[None, None, None, None, :] <= pos
    s = jnp.where(mask, s, -jnp.inf)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum(
        "bkgqs,bskd->bkgqd", w.astype(cv.dtype), cv,
        preferred_element_type=jnp.float32,
    )
    o = o.transpose(0, 3, 1, 2, 4).reshape(b, 1, kh * g, dh)
    out = jnp.einsum(
        "bshk,hkd->bsd", o.astype(cfg.dtype), p["wo"].astype(cfg.dtype)
    )
    return constrain(out, "batch", "seq", "embed"), {"k": ck, "v": cv}


# ---------------------------------------------------------- cross-attn
def cross_template(cfg: ModelConfig):
    """Encoder-decoder cross attention (whisper): KV from encoder memory."""
    return gqa_template(cfg)


def cross_forward(p, x, memory, cfg: ModelConfig):
    """x: (B,S,d) decoder; memory: (B,M,d) encoder output.  No RoPE."""
    x = x.astype(cfg.dtype)
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(cfg.dtype))
    k = jnp.einsum("bsd,dhk->bshk", memory.astype(cfg.dtype), p["wk"].astype(cfg.dtype))
    v = jnp.einsum("bsd,dhk->bshk", memory.astype(cfg.dtype), p["wv"].astype(cfg.dtype))
    q = constrain(q, "batch", "qk_seq", "heads", "head_dim")
    k = constrain(k, "batch", None, "kv_heads", "head_dim")
    v = constrain(v, "batch", None, "kv_heads", "head_dim")
    if "bq" in p:
        q = q + p["bq"].astype(cfg.dtype)
        k = k + p["bk"].astype(cfg.dtype)
        v = v + p["bv"].astype(cfg.dtype)
    out = chunked_attention(q, k, v, chunk=cfg.attn_chunk, causal=False,
                            unroll=not cfg.scan_layers)
    out = jnp.einsum("bshk,hkd->bsd", out.astype(cfg.dtype), p["wo"].astype(cfg.dtype))
    return constrain(out, "batch", "seq", "embed")


# ------------------------------------------------------------------ MLA
def mla_template(cfg: ModelConfig):
    d, h, pd = cfg.d_model, cfg.n_heads, cfg.param_dtype
    m = cfg.mla
    dqk = m.qk_nope_head_dim + m.qk_rope_head_dim
    return {
        "wq_a": ParamMeta((d, m.q_lora_rank), ("embed", "lora"), pd),
        "q_norm": ParamMeta((m.q_lora_rank,), ("lora",), pd, "ones"),
        "wq_b": ParamMeta((m.q_lora_rank, h, dqk), ("lora", "heads", "head_dim"), pd),
        "wkv_a": ParamMeta(
            (d, m.kv_lora_rank + m.qk_rope_head_dim), ("embed", "lora"), pd
        ),
        "kv_norm": ParamMeta((m.kv_lora_rank,), ("lora",), pd, "ones"),
        "wkv_b": ParamMeta(
            (m.kv_lora_rank, h, m.qk_nope_head_dim + m.v_head_dim),
            ("lora", "heads", "head_dim"),
            pd,
        ),
        "wo": ParamMeta((h, m.v_head_dim, d), ("heads", "head_dim", "embed"), pd),
    }


def _rms(x, w, eps):
    xf = x.astype(jnp.float32)
    return (
        xf * jax.lax.rsqrt(jnp.mean(xf * xf, -1, keepdims=True) + eps)
        * w.astype(jnp.float32)
    ).astype(x.dtype)


def _mla_qkr(p, x, cfg, positions):
    """Shared MLA projections: q (nope+rope'd), c_kv, k_rope."""
    m = cfg.mla
    x = x.astype(cfg.dtype)
    cq = _rms(x @ p["wq_a"].astype(cfg.dtype), p["q_norm"], cfg.norm_eps)
    q = jnp.einsum("bsr,rhk->bshk", cq, p["wq_b"].astype(cfg.dtype))
    q = constrain(q, "batch", "qk_seq", "heads", "head_dim")
    qn, qr = q[..., : m.qk_nope_head_dim], q[..., m.qk_nope_head_dim :]
    qr = apply_rope(qr, positions, cfg.rope_theta)
    kv = x @ p["wkv_a"].astype(cfg.dtype)
    c_kv = _rms(kv[..., : m.kv_lora_rank], p["kv_norm"], cfg.norm_eps)
    kr = kv[..., m.kv_lora_rank :][:, :, None, :]  # (B,S,1,rope)
    kr = apply_rope(kr, positions, cfg.rope_theta)
    return qn, qr, c_kv, kr


def mla_forward(p, x, cfg: ModelConfig, positions):
    """Training/prefill MLA (direct, un-absorbed form)."""
    m = cfg.mla
    h = cfg.n_heads
    qn, qr, c_kv, kr = _mla_qkr(p, x, cfg, positions)
    kvb = jnp.einsum("bsr,rhk->bshk", c_kv, p["wkv_b"].astype(cfg.dtype))
    kvb = constrain(kvb, "batch", "qk_seq", "heads", "head_dim")
    kn, v = kvb[..., : m.qk_nope_head_dim], kvb[..., m.qk_nope_head_dim :]
    q = jnp.concatenate([qn, qr], axis=-1)
    k = jnp.concatenate([kn, jnp.broadcast_to(kr, kn.shape[:-1] + (m.qk_rope_head_dim,))], axis=-1)
    out = chunked_attention(q, k, v, chunk=cfg.attn_chunk, causal=True,
                            unroll=not cfg.scan_layers)
    out = jnp.einsum("bshk,hkd->bsd", out.astype(cfg.dtype), p["wo"].astype(cfg.dtype))
    return constrain(out, "batch", "seq", "embed")


def mla_prefill(p, x, cfg: ModelConfig, positions, cache_len: int):
    out = mla_forward(p, x, cfg, positions)
    m = cfg.mla
    x = x.astype(cfg.dtype)
    kv = x @ p["wkv_a"].astype(cfg.dtype)
    c_kv = _rms(kv[..., : m.kv_lora_rank], p["kv_norm"], cfg.norm_eps)
    kr = apply_rope(
        kv[..., m.kv_lora_rank :][:, :, None, :], positions, cfg.rope_theta
    )[:, :, 0, :]
    b, s = x.shape[:2]
    pad = cache_len - s
    cache = {
        "c_kv": constrain(
            jnp.pad(c_kv, ((0, 0), (0, pad), (0, 0))), "batch", "kv_seq", None
        ),
        "k_rope": constrain(
            jnp.pad(kr, ((0, 0), (0, pad), (0, 0))), "batch", "kv_seq", None
        ),
    }
    return out, cache


def mla_decode(p, x, cfg: ModelConfig, cache, pos):
    """Absorbed-form MLA decode against the compressed cache."""
    m = cfg.mla
    qn, qr, c_kv_new, kr_new = _mla_qkr(p, x, cfg, pos[None])
    ck = jax.lax.dynamic_update_slice_in_dim(
        cache["c_kv"], c_kv_new.astype(cache["c_kv"].dtype), pos, axis=1
    )
    crp = jax.lax.dynamic_update_slice_in_dim(
        cache["k_rope"], kr_new[:, :, 0, :].astype(cache["k_rope"].dtype), pos, axis=1
    )
    wkb = p["wkv_b"].astype(cfg.dtype)
    wk = wkb[..., : m.qk_nope_head_dim]  # (r, H, dn)
    wv = wkb[..., m.qk_nope_head_dim :]  # (r, H, dv)
    # absorb: q̃ = qn @ wk^T  -> score against c_kv directly
    qt = jnp.einsum("bshk,rhk->bshr", qn, wk)  # (B,1,H,r)
    s_c = jnp.einsum("bshr,blr->bhsl", qt, ck, preferred_element_type=jnp.float32)
    s_r = jnp.einsum(
        "bshk,blk->bhsl", qr, crp, preferred_element_type=jnp.float32
    )
    scale = (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5
    s = (s_c + s_r) * scale
    l = ck.shape[1]
    mask = jnp.arange(l)[None, None, None, :] <= pos
    w = jax.nn.softmax(jnp.where(mask, s, -jnp.inf), axis=-1)
    o_c = jnp.einsum(
        "bhsl,blr->bshr", w.astype(ck.dtype), ck, preferred_element_type=jnp.float32
    )  # (B,1,H,r)
    o = jnp.einsum("bshr,rhk->bshk", o_c.astype(cfg.dtype), wv)  # (B,1,H,dv)
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(cfg.dtype))
    return (
        constrain(out, "batch", "seq", "embed"),
        {"c_kv": ck, "k_rope": crp},
    )
