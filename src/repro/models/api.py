"""Unified model API: template/loss/prefill/decode for any ModelConfig."""

from __future__ import annotations

import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models import encdec, transformer


def is_encdec(cfg: ModelConfig) -> bool:
    return cfg.n_encoder_layers > 0


def template(cfg: ModelConfig):
    return encdec.encdec_template(cfg) if is_encdec(cfg) else transformer.lm_template(cfg)


def loss_fn(params, batch, cfg: ModelConfig):
    if is_encdec(cfg):
        return encdec.encdec_loss(params, batch, cfg)
    return transformer.lm_loss(params, batch, cfg)


def prefill(params, batch, cfg: ModelConfig, cache_len: int):
    if is_encdec(cfg):
        return encdec.prefill(params, batch, cfg, cache_len)
    return transformer.prefill(
        params, batch["tokens"], cfg, cache_len,
        prefix_embeds=batch.get("prefix_embeds"),
    )


def decode_step(params, token, caches, pos, cfg: ModelConfig):
    if is_encdec(cfg):
        return encdec.decode_step(params, token, caches, pos, cfg)
    return transformer.decode_step(params, token, caches, pos, cfg)


def init_cache(cfg: ModelConfig, batch: int, cache_len: int):
    if is_encdec(cfg):
        return encdec.init_cache(cfg, batch, cache_len)
    return transformer.init_cache(cfg, batch, cache_len)


def cache_axes(cfg: ModelConfig):
    if is_encdec(cfg):
        return encdec.cache_axes(cfg)
    return transformer.cache_axes(cfg)


def make_batch_shapes(cfg: ModelConfig, batch: int, seq: int):
    """ShapeDtypeStruct batch for train/prefill (stub frontends included)."""
    import jax

    b = {
        "tokens": jax.ShapeDtypeStruct((batch, seq), jnp.int32),
        "targets": jax.ShapeDtypeStruct((batch, seq), jnp.int32),
    }
    if is_encdec(cfg):
        b["enc_frames"] = jax.ShapeDtypeStruct(
            (batch, cfg.encoder_positions, cfg.d_model), jnp.dtype(cfg.dtype)
        )
        del b["targets"]
        b["targets"] = jax.ShapeDtypeStruct((batch, seq), jnp.int32)
    elif cfg.frontend != "none" and cfg.frontend_len:
        b["prefix_embeds"] = jax.ShapeDtypeStruct(
            (batch, cfg.frontend_len, cfg.d_model), jnp.dtype(cfg.dtype)
        )
    return b
