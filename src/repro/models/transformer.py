"""Decoder-only LM assembly: periodic layer patterns, scan over periods.

Covers dense GQA (starcoder2/llama/qwen2), MLA (minicpm3), MoE
(moonshot/qwen3-moe), pure SSM (mamba2), and hybrid attn+mamba+MoE
(jamba) through one periodic ``layer_pattern``.  Layers are stacked
per-period and scanned (compile-time O(1) in depth) with configurable
remat.  VLM (internvl2) is the same decoder with stub prefix embeddings
concatenated ahead of the token embeddings.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.config import LayerSlot, ModelConfig
from repro.models import attention as attn
from repro.models import layers as L
from repro.models import mamba2, moe
from repro.models.meta import ParamMeta, is_meta, tree_map_meta
from repro.sharding import constrain


# ----------------------------------------------------------- templates
def _slot_template(cfg: ModelConfig, slot: LayerSlot):
    t = {}
    if slot.mixer != "none":
        t["ln"] = L.norm_template(cfg)
    if slot.mixer == "attn":
        t["attn"] = attn.gqa_template(cfg)
    elif slot.mixer == "mla":
        t["attn"] = attn.mla_template(cfg)
    elif slot.mixer == "mamba":
        t["mamba"] = mamba2.mamba_template(cfg)
    if slot.ffn != "none":
        t["ln2"] = L.norm_template(cfg)
    if slot.ffn == "dense":
        t["mlp"] = L.mlp_template(cfg)
    elif slot.ffn == "moe":
        t["moe"] = moe.moe_template(cfg)
    return t


def _stack_period(template, n_periods: int):
    return tree_map_meta(
        lambda m: ParamMeta(
            (n_periods,) + m.shape, ("layers",) + m.axes, m.dtype, m.init, m.scale
        ),
        template,
    )


def lm_template(cfg: ModelConfig):
    period = {
        f"slot{i}": _slot_template(cfg, s) for i, s in enumerate(cfg.layer_pattern)
    }
    return {
        "embed": L.embed_template(cfg),
        "period": _stack_period(period, cfg.n_periods),
        "final_norm": L.norm_template(cfg),
    }


# ------------------------------------------------------------- forward
def _apply_slot_train(p, x, cfg: ModelConfig, slot: LayerSlot, positions):
    aux = jnp.float32(0.0)
    if slot.mixer == "attn":
        x = x + attn.gqa_forward(p["attn"], L.norm_apply(p["ln"], x, cfg), cfg, positions)
    elif slot.mixer == "mla":
        x = x + attn.mla_forward(p["attn"], L.norm_apply(p["ln"], x, cfg), cfg, positions)
    elif slot.mixer == "mamba":
        x = x + mamba2.mamba_forward(p["mamba"], L.norm_apply(p["ln"], x, cfg), cfg)
    if slot.ffn == "dense":
        x = x + L.mlp_apply(p["mlp"], L.norm_apply(p["ln2"], x, cfg), cfg)
    elif slot.ffn == "moe":
        y, aux = moe.moe_apply(p["moe"], L.norm_apply(p["ln2"], x, cfg), cfg)
        x = x + y
    return x, aux


def _period_train(cfg: ModelConfig, positions):
    def fn(carry, pp):
        x, aux = carry
        for i, slot in enumerate(cfg.layer_pattern):
            x, a = _apply_slot_train(pp[f"slot{i}"], x, cfg, slot, positions)
            aux = aux + a
        x = constrain(x, "batch", "seq", "embed")
        return (x, aux), None

    return fn


def _remat(cfg: ModelConfig, fn):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        pol = jax.checkpoint_policies.checkpoint_dots
        return jax.checkpoint(fn, policy=pol)
    return jax.checkpoint(fn)


def _index_tree(tree, i):
    return jax.tree.map(lambda x: x[i], tree)


def _stack_trees(trees):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def _n_stacked(tree) -> int:
    return jax.tree.leaves(tree)[0].shape[0]


def _layer_loop(cfg: ModelConfig, fn, carry, stacked_params):
    """scan over periods, or an unrolled python loop (dry-run probes)."""
    if cfg.scan_layers:
        carry, _ = jax.lax.scan(fn, carry, stacked_params)
        return carry
    for i in range(_n_stacked(stacked_params)):
        carry, _ = fn(carry, _index_tree(stacked_params, i))
    return carry


def _layer_loop_cache(cfg: ModelConfig, fn, x, stacked_params, caches):
    """Like _layer_loop but threads/stacks per-period caches."""
    if cfg.scan_layers:
        if caches is None:
            return jax.lax.scan(fn, x, stacked_params)
        return jax.lax.scan(fn, x, (stacked_params, caches))
    outs = []
    for i in range(_n_stacked(stacked_params)):
        pp = _index_tree(stacked_params, i)
        inp = pp if caches is None else (pp, _index_tree(caches, i))
        x, out = fn(x, inp)
        outs.append(out)
    return x, _stack_trees(outs)


def lm_forward(params, tokens, cfg: ModelConfig, prefix_embeds=None):
    """tokens (B,S) -> final hidden states (B,S',d), S' = P + S with prefix."""
    x = L.embed_apply(params["embed"], tokens, cfg)
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
    bsz, s, _ = x.shape
    positions = jnp.arange(s)[None, :]
    fn = _remat(cfg, _period_train(cfg, positions))
    (x, aux) = _layer_loop(cfg, fn, (x, jnp.float32(0.0)), params["period"])
    x = L.norm_apply(params["final_norm"], x, cfg)
    return x, aux


def chunked_ce(params, x, targets, cfg: ModelConfig):
    """CE summed over (B,S): sequence-chunked so full logits never live."""
    b, s, d = x.shape
    c = min(cfg.loss_chunk, s)
    assert s % c == 0
    nch = s // c

    def chunk_loss(carry, inp):
        xc, tc = inp  # (B,c,d), (B,c)
        logits = L.unembed_apply(params["embed"], xc, cfg).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, tc[..., None], axis=-1)[..., 0]
        return carry + jnp.sum(lse - gold), None

    if nch == 1:
        total, _ = chunk_loss(jnp.float32(0.0), (x, targets))
        return total
    xs = (
        x.reshape(b, nch, c, d).transpose(1, 0, 2, 3),
        targets.reshape(b, nch, c).transpose(1, 0, 2),
    )
    if cfg.scan_layers:
        total, _ = jax.lax.scan(chunk_loss, jnp.float32(0.0), xs)
        return total
    total = jnp.float32(0.0)  # unrolled (dry-run probes)
    for i in range(nch):
        total, _ = chunk_loss(total, (xs[0][i], xs[1][i]))
    return total


def lm_loss(params, batch, cfg: ModelConfig, *, aux_weight: float = 0.01):
    tokens = batch["tokens"]
    targets = batch["targets"]
    prefix = batch.get("prefix_embeds")
    x, aux = lm_forward(params, tokens, cfg, prefix_embeds=prefix)
    if prefix is not None:
        x = x[:, prefix.shape[1] :, :]  # loss on text positions only
    b, s, _ = x.shape
    loss = chunked_ce(params, x, targets, cfg) / (b * s)
    return loss + aux_weight * aux / max(cfg.n_layers, 1)


# ------------------------------------------------------------- serving
def init_cache(cfg: ModelConfig, batch: int, cache_len: int):
    """Abstract-friendly cache pytree (stacked over periods)."""
    dt = jnp.dtype(cfg.dtype)
    ent = {}
    for i, slot in enumerate(cfg.layer_pattern):
        e = {}
        if slot.mixer == "attn":
            e = {
                "k": jnp.zeros((batch, cache_len, cfg.n_kv_heads, cfg.dh), dt),
                "v": jnp.zeros((batch, cache_len, cfg.n_kv_heads, cfg.dh), dt),
            }
        elif slot.mixer == "mla":
            m = cfg.mla
            e = {
                "c_kv": jnp.zeros((batch, cache_len, m.kv_lora_rank), dt),
                "k_rope": jnp.zeros((batch, cache_len, m.qk_rope_head_dim), dt),
            }
        elif slot.mixer == "mamba":
            e = mamba2.mamba_init_cache(cfg, batch, dt)
        ent[f"slot{i}"] = e
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x, (cfg.n_periods,) + x.shape), ent
    )


def cache_axes(cfg: ModelConfig):
    """Logical axes tree matching init_cache output (for shardings)."""
    ent = {}
    for i, slot in enumerate(cfg.layer_pattern):
        e = {}
        if slot.mixer == "attn":
            e = {"k": ("layers", "batch", "kv_seq", "kv_heads", "head_dim"),
                 "v": ("layers", "batch", "kv_seq", "kv_heads", "head_dim")}
        elif slot.mixer == "mla":
            e = {"c_kv": ("layers", "batch", "kv_seq", None),
                 "k_rope": ("layers", "batch", "kv_seq", None)}
        elif slot.mixer == "mamba":
            e = {"conv": ("layers", "batch", None, "ssm_inner"),
                 "ssm": ("layers", "batch", None, None, None)}
        ent[f"slot{i}"] = e
    return ent


def _apply_slot_decode(p, x, cfg, slot, cache, pos):
    if slot.mixer == "attn":
        y, cache2 = attn.gqa_decode(p["attn"], L.norm_apply(p["ln"], x, cfg), cfg, cache, pos)
        x = x + y
    elif slot.mixer == "mla":
        y, cache2 = attn.mla_decode(p["attn"], L.norm_apply(p["ln"], x, cfg), cfg, cache, pos)
        x = x + y
    elif slot.mixer == "mamba":
        y, cache2 = mamba2.mamba_decode(p["mamba"], L.norm_apply(p["ln"], x, cfg), cfg, cache)
        x = x + y
    else:
        cache2 = cache
    if slot.ffn == "dense":
        x = x + L.mlp_apply(p["mlp"], L.norm_apply(p["ln2"], x, cfg), cfg)
    elif slot.ffn == "moe":
        y, _ = moe.moe_apply(p["moe"], L.norm_apply(p["ln2"], x, cfg), cfg)
        x = x + y
    return x, cache2


def decode_step(params, token, caches, pos, cfg: ModelConfig):
    """token (B,1) int32; pos scalar int32 -> (logits (B,V), new caches)."""
    x = L.embed_apply(params["embed"], token, cfg)

    def fn(x, inp):
        pp, cache = inp
        new = {}
        for i, slot in enumerate(cfg.layer_pattern):
            x, new[f"slot{i}"] = _apply_slot_decode(
                pp[f"slot{i}"], x, cfg, slot, cache[f"slot{i}"], pos
            )
        return x, new

    x, new_caches = _layer_loop_cache(cfg, fn, x, params["period"], caches)
    x = L.norm_apply(params["final_norm"], x, cfg)
    logits = L.unembed_apply(params["embed"], x, cfg)[:, 0, :]
    return logits, new_caches


def _apply_slot_prefill(p, x, cfg, slot, positions, cache_len):
    if slot.mixer == "attn":
        y, cache = attn.gqa_prefill(
            p["attn"], L.norm_apply(p["ln"], x, cfg), cfg, positions, cache_len
        )
        x = x + y
    elif slot.mixer == "mla":
        y, cache = attn.mla_prefill(
            p["attn"], L.norm_apply(p["ln"], x, cfg), cfg, positions, cache_len
        )
        x = x + y
    elif slot.mixer == "mamba":
        y, cache = mamba2.mamba_forward(
            p["mamba"], L.norm_apply(p["ln"], x, cfg), cfg, return_state=True
        )
        x = x + y
    else:
        cache = {}
    if slot.ffn == "dense":
        x = x + L.mlp_apply(p["mlp"], L.norm_apply(p["ln2"], x, cfg), cfg)
    elif slot.ffn == "moe":
        y, _ = moe.moe_apply(p["moe"], L.norm_apply(p["ln2"], x, cfg), cfg)
        x = x + y
    return x, cache


def prefill(params, tokens, cfg: ModelConfig, cache_len: int, prefix_embeds=None):
    """tokens (B,S) -> (last-position logits (B,V), caches for decode)."""
    x = L.embed_apply(params["embed"], tokens, cfg)
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
    s = x.shape[1]
    positions = jnp.arange(s)[None, :]

    def fn(x, pp):
        caches = {}
        for i, slot in enumerate(cfg.layer_pattern):
            x, caches[f"slot{i}"] = _apply_slot_prefill(
                pp[f"slot{i}"], x, cfg, slot, positions, cache_len
            )
        x = constrain(x, "batch", "seq", "embed")
        return x, caches

    x, caches = _layer_loop_cache(cfg, fn, x, params["period"], None)
    x = L.norm_apply(params["final_norm"], x, cfg)
    logits = L.unembed_apply(params["embed"], x[:, -1:, :], cfg)[:, 0, :]
    return logits, caches
