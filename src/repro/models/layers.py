"""Shared layers: norms, MLPs, embeddings, RoPE (pure functional)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models.meta import ParamMeta
from repro.sharding import constrain


# ---------------------------------------------------------------- norms
def norm_template(cfg: ModelConfig):
    d = cfg.d_model
    t = {"w": ParamMeta((d,), ("embed",), cfg.param_dtype, "ones")}
    if cfg.norm == "layernorm":
        t["b"] = ParamMeta((d,), ("embed",), cfg.param_dtype, "zeros")
    return t


def norm_apply(p, x, cfg: ModelConfig):
    xf = x.astype(jnp.float32)
    if cfg.norm == "rmsnorm":
        var = jnp.mean(xf * xf, axis=-1, keepdims=True)
        out = xf * jax.lax.rsqrt(var + cfg.norm_eps) * p["w"].astype(jnp.float32)
    else:
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        out = (xf - mu) * jax.lax.rsqrt(var + cfg.norm_eps)
        out = out * p["w"].astype(jnp.float32) + p["b"].astype(jnp.float32)
    return out.astype(cfg.dtype)


# ---------------------------------------------------------------- MLP
def mlp_template(cfg: ModelConfig, d_ff: int | None = None):
    d, ff, pd = cfg.d_model, d_ff or cfg.d_ff, cfg.param_dtype
    if cfg.activation == "swiglu":
        return {
            "wg": ParamMeta((d, ff), ("embed", "mlp"), pd),
            "wu": ParamMeta((d, ff), ("embed", "mlp"), pd),
            "wd": ParamMeta((ff, d), ("mlp", "embed"), pd),
        }
    return {
        "w1": ParamMeta((d, ff), ("embed", "mlp"), pd),
        "b1": ParamMeta((ff,), ("mlp",), pd, "zeros"),
        "w2": ParamMeta((ff, d), ("mlp", "embed"), pd),
        "b2": ParamMeta((d,), ("embed",), pd, "zeros"),
    }


def mlp_apply(p, x, cfg: ModelConfig):
    x = x.astype(cfg.dtype)
    if cfg.activation == "swiglu":
        g = x @ p["wg"].astype(cfg.dtype)
        u = x @ p["wu"].astype(cfg.dtype)
        h = jax.nn.silu(g) * u
        h = constrain(h, "batch", "seq", "mlp")
        return h @ p["wd"].astype(cfg.dtype)
    h = jax.nn.gelu(x @ p["w1"].astype(cfg.dtype) + p["b1"].astype(cfg.dtype))
    h = constrain(h, "batch", "seq", "mlp")
    return h @ p["w2"].astype(cfg.dtype) + p["b2"].astype(cfg.dtype)


# ---------------------------------------------------------------- embed
def embed_template(cfg: ModelConfig):
    v = cfg.padded_vocab
    t = {
        "tok": ParamMeta(
            (v, cfg.d_model), ("vocab", "embed"), cfg.param_dtype, "small"
        )
    }
    if not cfg.tie_embeddings:
        t["unembed"] = ParamMeta(
            (cfg.d_model, v), ("embed", "vocab"), cfg.param_dtype
        )
    return t


def embed_apply(p, tokens, cfg: ModelConfig):
    out = jnp.take(p["tok"].astype(cfg.dtype), tokens, axis=0)
    return constrain(out, "batch", "seq", "embed")


def unembed_apply(p, x, cfg: ModelConfig):
    """Logits over the PADDED vocab; pad columns masked to -inf-ish."""
    w = p["tok"].T if cfg.tie_embeddings else p["unembed"]
    logits = x.astype(cfg.dtype) @ w.astype(cfg.dtype)
    if cfg.padded_vocab != cfg.vocab:
        pad = jnp.arange(cfg.padded_vocab) >= cfg.vocab
        logits = jnp.where(pad, jnp.asarray(-1e9, logits.dtype), logits)
    return constrain(logits, "batch", "seq", "vocab")


# ---------------------------------------------------------------- RoPE
def rope_angles(positions, dh: int, theta: float):
    """positions (...,) int -> (..., dh/2) angles."""
    inv = 1.0 / (
        theta ** (jnp.arange(0, dh, 2, dtype=jnp.float32) / dh)
    )  # (dh/2,)
    return positions[..., None].astype(jnp.float32) * inv


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, D); positions broadcastable to (..., S)."""
    d = x.shape[-1]
    ang = rope_angles(positions, d, theta)  # (..., S, D/2)
    cos = jnp.cos(ang)[..., None, :]  # (..., S, 1, D/2)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., : d // 2], x[..., d // 2 :]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate(
        [xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1
    )
    return out.astype(x.dtype)
