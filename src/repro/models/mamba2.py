"""Mamba-2 block: SSD (state-space duality) chunked scan + O(1) decode.

Chunked algorithm per the Mamba-2 paper (arXiv:2405.21060, Listing 1):
intra-chunk quadratic term + inter-chunk state recurrence.  The
recurrence is a lax.scan over chunks (linear in chunk count, stable in
f32), which is also what makes the long_500k decode shape sub-quadratic:
the decode step is a single state update, O(d_state) per channel.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models.meta import ParamMeta
from repro.sharding import constrain


def dims(cfg: ModelConfig):
    ss = cfg.ssm
    d_inner = ss.expand * cfg.d_model
    n_heads = d_inner // ss.head_dim
    conv_dim = d_inner + 2 * ss.n_groups * ss.d_state
    d_in_proj = 2 * d_inner + 2 * ss.n_groups * ss.d_state + n_heads
    return d_inner, n_heads, conv_dim, d_in_proj


def mamba_template(cfg: ModelConfig):
    ss, pd = cfg.ssm, cfg.param_dtype
    d_inner, n_heads, conv_dim, d_in_proj = dims(cfg)
    return {
        "in_proj": ParamMeta((cfg.d_model, d_in_proj), ("embed", "ssm_inner"), pd),
        "conv_w": ParamMeta((ss.d_conv, conv_dim), ("conv", "ssm_inner"), pd, "small"),
        "conv_b": ParamMeta((conv_dim,), ("ssm_inner",), pd, "zeros"),
        "a_log": ParamMeta((n_heads,), (None,), "float32", "ones"),
        "d_skip": ParamMeta((n_heads,), (None,), "float32", "ones"),
        "dt_bias": ParamMeta((n_heads,), (None,), "float32", "zeros"),
        "norm_w": ParamMeta((d_inner,), ("ssm_inner",), pd, "ones"),
        "out_proj": ParamMeta((d_inner, cfg.d_model), ("ssm_inner", "embed"), pd),
    }


def _split_proj(cfg, proj):
    ss = cfg.ssm
    d_inner, n_heads, conv_dim, _ = dims(cfg)
    z, xbc, dt = jnp.split(proj, [d_inner, d_inner + conv_dim], axis=-1)
    return z, xbc, dt


def _split_xbc(cfg, xbc):
    ss = cfg.ssm
    d_inner, *_ = dims(cfg)
    gn = ss.n_groups * ss.d_state
    x, b, c = jnp.split(xbc, [d_inner, d_inner + gn], axis=-1)
    return x, b, c


def _gated_norm(y, z, w, eps):
    yf = (y * jax.nn.silu(z.astype(jnp.float32))).astype(jnp.float32)
    var = jnp.mean(yf * yf, axis=-1, keepdims=True)
    return yf * jax.lax.rsqrt(var + eps) * w.astype(jnp.float32)


def mamba_forward(p, xin, cfg: ModelConfig, return_state: bool = False):
    """Full-sequence SSD.  xin: (B,S,d).  Optionally returns final caches
    (conv tail + SSM state) for prefill->decode handoff."""
    ss = cfg.ssm
    d_inner, n_heads, conv_dim, _ = dims(cfg)
    bsz, s, _ = xin.shape
    q = min(ss.chunk, s)
    assert s % q == 0
    nc = s // q
    hd, ns, g = ss.head_dim, ss.d_state, ss.n_groups

    proj = xin.astype(cfg.dtype) @ p["in_proj"].astype(cfg.dtype)
    z, xbc, dt = _split_proj(cfg, proj)

    # causal depthwise conv1d (kernel d_conv) over sequence
    pad = jnp.zeros((bsz, ss.d_conv - 1, conv_dim), xbc.dtype)
    xbc_pad = jnp.concatenate([pad, xbc], axis=1)
    conv_tail = xbc_pad[:, -(ss.d_conv - 1):, :] if return_state else None
    wc = p["conv_w"].astype(cfg.dtype)  # (d_conv, conv_dim)
    xbc = sum(
        xbc_pad[:, i : i + s, :] * wc[i][None, None, :] for i in range(ss.d_conv)
    ) + p["conv_b"].astype(cfg.dtype)
    xbc = jax.nn.silu(xbc)

    xs, b, c = _split_xbc(cfg, xbc)
    xh = xs.reshape(bsz, s, n_heads, hd)
    bg = b.reshape(bsz, s, g, ns)
    cg = c.reshape(bsz, s, g, ns)
    hpg = n_heads // g  # heads per B/C group

    dt = jax.nn.softplus(
        dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32)
    )  # (B,S,H)
    a = -jnp.exp(p["a_log"].astype(jnp.float32))  # (H,)
    da = dt * a[None, None, :]  # (B,S,H) decay log
    xdt = xh.astype(jnp.float32) * dt[..., None]  # dt-weighted input

    # chunk views
    dac = da.reshape(bsz, nc, q, n_heads)
    da_cs = jnp.cumsum(dac, axis=2)  # (B,nc,Q,H)
    xc = xdt.reshape(bsz, nc, q, n_heads, hd)
    bc = bg.reshape(bsz, nc, q, g, ns).astype(jnp.float32)
    cc = cg.reshape(bsz, nc, q, g, ns).astype(jnp.float32)

    # intra-chunk (diagonal) term.  Mask BEFORE exp: the upper triangle is
    # positive and would overflow to inf, poisoning grads through where().
    li = da_cs[:, :, :, None, :] - da_cs[:, :, None, :, :]  # (B,nc,Qi,Qj,H)
    mask = (jnp.arange(q)[:, None] >= jnp.arange(q)[None, :])[None, None, :, :, None]
    l = jnp.where(mask, jnp.exp(jnp.where(mask, li, 0.0)), 0.0)
    cb = jnp.einsum("bcign,bcjgn->bcijg", cc, bc)  # (B,nc,Qi,Qj,g)
    cb = jnp.repeat(cb, hpg, axis=-1)  # -> per head
    y_diag = jnp.einsum("bcijh,bcijh,bcjhp->bcihp", cb, l, xc)

    # chunk states + inter-chunk scan
    decay_states = jnp.exp(da_cs[:, :, -1:, :] - da_cs)  # (B,nc,Q,H)
    states = jnp.einsum(
        "bcqgn,bcqh,bcqhp->bchpn",
        bc,
        decay_states,
        xc.reshape(bsz, nc, q, n_heads, hd),
    )  # (B,nc,H,P,N)
    chunk_decay = jnp.exp(da_cs[:, :, -1, :])  # (B,nc,H)

    def scan_fn(h0, inp):
        st, dec = inp  # (B,H,P,N), (B,H)
        h1 = h0 * dec[..., None, None] + st
        return h1, h0  # emit state at chunk START

    h_init = jnp.zeros((bsz, n_heads, hd, ns), jnp.float32)
    st_t = states.transpose(1, 0, 2, 3, 4)
    dec_t = chunk_decay.transpose(1, 0, 2)
    if cfg.scan_layers:
        h_last, h_starts = jax.lax.scan(scan_fn, h_init, (st_t, dec_t))
    else:  # unrolled for the dry-run probes (cost_analysis fidelity)
        hs, h = [], h_init
        for i in range(nc):
            h, h0 = scan_fn(h, (st_t[i], dec_t[i]))
            hs.append(h0)
        h_last, h_starts = h, jnp.stack(hs)
    h_starts = h_starts.transpose(1, 0, 2, 3, 4)  # (B,nc,H,P,N)

    # inter-chunk (off-diagonal) output
    state_decay = jnp.exp(da_cs)  # (B,nc,Q,H)
    cch = jnp.repeat(cc, hpg, axis=3)  # (B,nc,Q,H,N)
    y_off = jnp.einsum("bcqhn,bchpn,bcqh->bcqhp", cch, h_starts, state_decay)

    y = (y_diag + y_off).reshape(bsz, s, n_heads, hd)
    y = y + p["d_skip"].astype(jnp.float32)[None, None, :, None] * xh.astype(
        jnp.float32
    )
    y = y.reshape(bsz, s, d_inner)
    y = _gated_norm(y, z, p["norm_w"], cfg.norm_eps)
    out = y.astype(cfg.dtype) @ p["out_proj"].astype(cfg.dtype)
    out = constrain(out, "batch", "seq", "embed")
    if return_state:
        return out, {"conv": conv_tail, "ssm": h_last}
    return out


def mamba_init_cache(cfg: ModelConfig, batch: int, dtype):
    ss = cfg.ssm
    d_inner, n_heads, conv_dim, _ = dims(cfg)
    return {
        "conv": jnp.zeros((batch, ss.d_conv - 1, conv_dim), dtype),
        "ssm": jnp.zeros((batch, n_heads, ss.head_dim, ss.d_state), jnp.float32),
    }


def mamba_decode(p, xin, cfg: ModelConfig, cache):
    """Single-token step.  xin: (B,1,d); cache: {conv, ssm}."""
    ss = cfg.ssm
    d_inner, n_heads, conv_dim, _ = dims(cfg)
    bsz = xin.shape[0]
    hd, ns, g = ss.head_dim, ss.d_state, ss.n_groups

    proj = xin[:, 0, :].astype(cfg.dtype) @ p["in_proj"].astype(cfg.dtype)
    z, xbc, dt = _split_proj(cfg, proj)  # (B, ...)

    conv_buf = jnp.concatenate([cache["conv"], xbc[:, None, :]], axis=1)
    wc = p["conv_w"].astype(cfg.dtype)
    xbc = jnp.einsum("bkc,kc->bc", conv_buf, wc) + p["conv_b"].astype(cfg.dtype)
    xbc = jax.nn.silu(xbc)
    new_conv = conv_buf[:, 1:, :]

    xs, b, c = _split_xbc(cfg, xbc)
    xh = xs.reshape(bsz, n_heads, hd).astype(jnp.float32)
    bg = b.reshape(bsz, g, ns).astype(jnp.float32)
    cg = c.reshape(bsz, g, ns).astype(jnp.float32)
    hpg = n_heads // g

    dt = jax.nn.softplus(
        dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32)
    )  # (B,H)
    a = -jnp.exp(p["a_log"].astype(jnp.float32))
    dec = jnp.exp(dt * a[None, :])  # (B,H)
    bh = jnp.repeat(bg, hpg, axis=1)  # (B,H,N)
    ch = jnp.repeat(cg, hpg, axis=1)
    h = cache["ssm"] * dec[..., None, None] + jnp.einsum(
        "bh,bhp,bhn->bhpn", dt, xh, bh
    )
    y = jnp.einsum("bhpn,bhn->bhp", h, ch) + p["d_skip"].astype(jnp.float32)[
        None, :, None
    ] * xh
    y = y.reshape(bsz, d_inner)
    y = _gated_norm(y, z, p["norm_w"], cfg.norm_eps)
    out = (y.astype(cfg.dtype) @ p["out_proj"].astype(cfg.dtype))[:, None, :]
    return constrain(out, "batch", "seq", "embed"), {"conv": new_conv, "ssm": h}
