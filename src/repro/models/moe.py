"""Mixture-of-Experts with SAMPLE-SORT dispatch (the paper, first-class).

Token dispatch is the bucket phase of GPU BUCKET SORT with the router's
expert ids as precomputed bucket assignments: stable sort of
(expert_id, slot) pairs (steps 1-2 analogue), per-expert counts +
column prefix sum (step 7), one relocation scatter into the dense
(E, capacity, d) buffer (step 8).  Determinism => static capacity and
bitwise-reproducible routing (checkpoint/restart safe), exactly the
property the paper argues for.

Dispatch impls:
  sample_sort — stable bucket-sort argsort of expert ids (ours)
  xla_sort    — jnp.argsort baseline (same layout, vendor sort)
  onehot      — GShard-style dense one-hot einsum dispatch (no sort);
                most GSPMD-friendly, used as a compile fallback/ablation
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.core import bucket_sort
from repro.core.sort_config import SortConfig, round_up
from repro.models.meta import ParamMeta
from repro.sharding import constrain

_DISPATCH_SORT_CFG = SortConfig(tile=2048, s=64, direct_max=8192)


def moe_template(cfg: ModelConfig):
    d, pd = cfg.d_model, cfg.param_dtype
    mo = cfg.moe
    e, ff = mo.n_experts, mo.d_ff_expert
    t = {
        "router": ParamMeta((d, e), ("embed", None), "float32", "small"),
        "wg": ParamMeta((e, d, ff), ("expert", "embed", "mlp"), pd),
        "wu": ParamMeta((e, d, ff), ("expert", "embed", "mlp"), pd),
        "wd": ParamMeta((e, ff, d), ("expert", "mlp", "embed"), pd),
    }
    if mo.n_shared_experts:
        sff = mo.n_shared_experts * ff
        t["shared"] = {
            "wg": ParamMeta((d, sff), ("embed", "mlp"), pd),
            "wu": ParamMeta((d, sff), ("embed", "mlp"), pd),
            "wd": ParamMeta((sff, d), ("mlp", "embed"), pd),
        }
    return t


def _topk_gates(logits, k: int, impl: str):
    """(N,E) f32 logits -> (N,k) normalized gates + (N,k) int32 ids."""
    probs = jax.nn.softmax(logits, axis=-1)
    if impl == "sample_sort":
        from repro.kernels import ops as kops

        vals, ids = kops.topk(probs, k)
    else:
        vals, ids = jax.lax.top_k(probs, k)
    gates = vals / jnp.maximum(vals.sum(-1, keepdims=True), 1e-9)
    return gates.astype(jnp.float32), ids.astype(jnp.int32)


def _rank_in_expert_sort(ids_flat, e: int, impl: str):
    """Within-expert rank of each slot via STABLE sort (steps 6-8 analogue).

    Returns (rank (M,), counts (E,)).
    """
    m = ids_flat.shape[0]
    if impl == "sample_sort":
        perm = bucket_sort.argsort(ids_flat, _DISPATCH_SORT_CFG)
    else:
        perm = jnp.argsort(ids_flat, stable=True).astype(jnp.int32)
    sorted_ids = jnp.take(ids_flat, perm)
    counts = jnp.zeros((e,), jnp.int32).at[ids_flat].add(1)
    starts = jnp.cumsum(counts) - counts  # (E,) exclusive
    r_sorted = jnp.arange(m, dtype=jnp.int32) - jnp.take(starts, sorted_ids)
    rank = jnp.zeros((m,), jnp.int32).at[perm].set(r_sorted)
    return rank, counts


def _rank_in_expert_onehot(ids_flat, e: int):
    """GShard-style dense rank: cumsum over a one-hot (M,E) matrix."""
    oh = jax.nn.one_hot(ids_flat, e, dtype=jnp.int32)  # (M,E)
    rank = (jnp.cumsum(oh, axis=0) - oh)  # rank within expert
    rank = jnp.sum(rank * oh, axis=-1)
    counts = jnp.sum(oh, axis=0)
    return rank.astype(jnp.int32), counts.astype(jnp.int32)


def moe_apply(p, x, cfg: ModelConfig):
    """x: (B,S,d) -> (out (B,S,d), aux_loss scalar)."""
    mo = cfg.moe
    b, s, d = x.shape
    n = b * s
    e, k = mo.n_experts, mo.top_k
    # capacity rounded to 128: lane-aligned AND divisible by every
    # data-axis size so the (E, capacity, d) buffers shard over "data"
    # (a non-divisible capacity silently replicates the expert einsum
    # across the data axis — measured 16x flop inflation).
    cap = round_up(int(mo.capacity_factor * n * k / e) + 1, 128)

    xf = x.reshape(n, d)
    logits = xf.astype(jnp.float32) @ p["router"].astype(jnp.float32)
    gates, ids = _topk_gates(logits, k, mo.dispatch)  # (N,k)

    # load-balance aux loss (Switch): E * sum_e f_e * p_e
    probs = jax.nn.softmax(logits, axis=-1)
    f_e = jnp.mean(
        jnp.sum(jax.nn.one_hot(ids, e, dtype=jnp.float32), axis=1), axis=0
    )
    aux = e * jnp.sum(f_e * jnp.mean(probs, axis=0))

    ids_flat = ids.reshape(n * k)
    if mo.dispatch == "onehot":
        rank, counts = _rank_in_expert_onehot(ids_flat, e)
    else:
        rank, counts = _rank_in_expert_sort(ids_flat, e, mo.dispatch)

    keep = rank < cap
    dest = jnp.where(keep, ids_flat * cap + rank, e * cap)  # drop overflow

    # relocation (step 8): one scatter builds the gather map
    src = jnp.full((e * cap + 1,), n, jnp.int32)
    slot_token = jnp.arange(n * k, dtype=jnp.int32) // k
    src = src.at[dest].set(slot_token, mode="drop")[: e * cap]
    x_pad = jnp.concatenate([xf, jnp.zeros((1, d), xf.dtype)], axis=0)
    x_e = jnp.take(x_pad, src, axis=0).reshape(e, cap, d)
    x_e = constrain(x_e, "expert", "capacity", "embed")

    # expert FFN (stacked einsum; experts sharded over "model")
    dt = cfg.dtype
    g = jnp.einsum("ecd,edf->ecf", x_e.astype(dt), p["wg"].astype(dt))
    u = jnp.einsum("ecd,edf->ecf", x_e.astype(dt), p["wu"].astype(dt))
    h = jax.nn.silu(g) * u
    h = constrain(h, "expert", "capacity", "mlp")
    y_e = jnp.einsum("ecf,efd->ecd", h, p["wd"].astype(dt))
    y_e = constrain(y_e, "expert", "capacity", "embed")

    # combine: gather back per slot, weight, sum over k
    y_flat = y_e.reshape(e * cap, d)
    y_pad = jnp.concatenate([y_flat, jnp.zeros((1, d), y_flat.dtype)], axis=0)
    slot_y = jnp.take(y_pad, jnp.minimum(dest, e * cap), axis=0)  # (N*k, d)
    w = jnp.where(keep, gates.reshape(n * k), 0.0).astype(jnp.float32)
    out = jnp.sum(
        (slot_y.astype(jnp.float32) * w[:, None]).reshape(n, k, d), axis=1
    )

    if mo.n_shared_experts:
        sp = p["shared"]
        sg = xf.astype(dt) @ sp["wg"].astype(dt)
        su = xf.astype(dt) @ sp["wu"].astype(dt)
        out = out + (
            (jax.nn.silu(sg) * su) @ sp["wd"].astype(dt)
        ).astype(jnp.float32)

    return out.reshape(b, s, d).astype(cfg.dtype), aux
