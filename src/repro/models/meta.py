"""Parameter templates: single source of truth for shapes/dtypes/sharding.

Models describe their parameters as a pytree of ``ParamMeta`` leaves;
from it we derive (a) materialized params for tests/training, (b)
ShapeDtypeStruct trees for the dry-run (.lower/.compile with zero
allocation), (c) PartitionSpec trees via the logical-axis rules.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro import sharding


@dataclasses.dataclass(frozen=True)
class ParamMeta:
    shape: tuple[int, ...]
    axes: tuple[str | None, ...]  # logical axes, len == len(shape)
    dtype: str = "float32"
    init: str = "normal"  # normal | zeros | ones | small
    scale: float | None = None  # None -> 1/sqrt(fan_in)

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def is_meta(x) -> bool:
    return isinstance(x, ParamMeta)


def tree_map_meta(f, template):
    return jax.tree.map(f, template, is_leaf=is_meta)


def abstract_params(template):
    """ShapeDtypeStruct tree (for jit.lower / eval_shape)."""
    return tree_map_meta(
        lambda m: jax.ShapeDtypeStruct(m.shape, jnp.dtype(m.dtype)), template
    )


def param_specs(template, rules, axis_sizes):
    """PartitionSpec tree from logical axes (divisibility-aware)."""
    return tree_map_meta(
        lambda m: sharding.resolve(m.axes, rules, axis_sizes, shape=m.shape),
        template,
    )


def init_params(template, key):
    """Materialize parameters (tests / real training)."""
    leaves, treedef = jax.tree.flatten(template, is_leaf=is_meta)
    keys = jax.random.split(key, len(leaves))

    def mk(m: ParamMeta, k):
        dt = jnp.dtype(m.dtype)
        if m.init == "zeros":
            return jnp.zeros(m.shape, dt)
        if m.init == "ones":
            return jnp.ones(m.shape, dt)
        fan_in = m.shape[0] if len(m.shape) >= 1 else 1
        scale = m.scale if m.scale is not None else 1.0 / max(fan_in, 1) ** 0.5
        if m.init == "small":
            scale = 0.02
        return (jax.random.normal(k, m.shape, jnp.float32) * scale).astype(dt)

    return jax.tree.unflatten(treedef, [mk(m, k) for m, k in zip(leaves, keys)])


def count_params(template) -> int:
    import math

    leaves = jax.tree.leaves(template, is_leaf=is_meta)
    return sum(math.prod(m.shape) for m in leaves)
