"""Training launcher: real steps on the available devices.

  PYTHONPATH=src python -m repro.launch.train --arch qwen2-1.5b --smoke \
      --steps 50 --batch 8 --seq 128

--smoke uses the reduced config (CPU-friendly); production runs use the
full config on a real mesh (same code path, bigger ParallelConfig).
Fault tolerance: auto-resume from the newest checkpoint, async saves,
straggler logging, elastic mesh fit (runtime/driver.py).
"""

from __future__ import annotations

import argparse
import dataclasses

import jax
import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true", help="reduced config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--mesh", default=None, help="e.g. 4x2 => data x model")
    ap.add_argument("--dispatch", default=None)
    args = ap.parse_args()

    from repro import configs, sharding as shd
    from repro.config import OptimizerConfig, ParallelConfig, ShapeConfig
    from repro.data import SyntheticDataset
    from repro.launch.mesh import make_mesh
    from repro.launch.steps import (
        build_train_step, make_plan, param_shardings,
    )
    from repro.models import api, meta
    from repro.optim import adamw_init
    from repro.runtime import StragglerMonitor, TrainDriver

    arch = configs.get_config(args.arch)
    model = configs.get_smoke(args.arch) if args.smoke else arch.model
    if args.dispatch and model.moe is not None:
        model = dataclasses.replace(
            model, moe=dataclasses.replace(model.moe, dispatch=args.dispatch)
        )
    arch = dataclasses.replace(arch, model=model)

    n_dev = len(jax.devices())
    if args.mesh:
        shape = tuple(int(x) for x in args.mesh.split("x"))
    else:
        shape = (n_dev, 1)
    par = ParallelConfig(mesh_shape=shape, mesh_axes=("data", "model"))
    mesh = make_mesh(shape, ("data", "model"))
    shp = ShapeConfig("cli", args.seq, args.batch, "train")
    plan = make_plan(arch, shp, mesh, par)
    opt = OptimizerConfig(lr=args.lr, total_steps=args.steps,
                          warmup_steps=max(args.steps // 10, 1),
                          moment_dtype=arch.moment_dtype)

    tpl = api.template(model)
    print(f"[train] {model.name}: {meta.count_params(tpl)/1e6:.1f}M params, "
          f"mesh {shape}, batch {args.batch} x seq {args.seq}")

    p_sh = param_shardings(plan)
    step_raw = build_train_step(plan, opt)

    def step_fn(state, batch):
        batch = {k: jax.numpy.asarray(v) for k, v in batch.items()}
        params, opt_state = state
        params, opt_state, metrics = jitted(params, opt_state, batch)
        return (params, opt_state), metrics

    with shd.sharding_ctx(mesh, plan.rules):
        jitted = jax.jit(step_raw, donate_argnums=(0, 1))

        def init_state():
            params = meta.init_params(tpl, jax.random.PRNGKey(0))
            params = jax.tree.map(jax.device_put, params, p_sh)
            return (params, adamw_init(params, opt))

        ds = SyntheticDataset(model.vocab, args.seq, args.batch, seed=0)
        driver = TrainDriver(
            step_fn, init_state, ds,
            ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
            log_every=max(args.steps // 20, 1),
            monitor=StragglerMonitor(heartbeat_path=args.ckpt_dir + "/heartbeat.json"),
        )
        state, history = driver.run(args.steps)

    losses = [h["loss"] for h in history]
    print(f"[train] done: loss {losses[0]:.3f} -> {losses[-1]:.3f}")
    assert np.isfinite(losses[-1])


if __name__ == "__main__":
    main()
