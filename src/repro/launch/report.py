"""Generate EXPERIMENTS.md roofline/dry-run tables from results/dryrun.

  PYTHONPATH=src python -m repro.launch.report --dryrun results/dryrun
"""

from __future__ import annotations

import argparse
import glob
import json
import os


def load(dirpath):
    cells = []
    for f in sorted(glob.glob(os.path.join(dirpath, "*.json"))):
        with open(f) as fh:
            cells.append(json.load(fh))
    return cells


def fmt_s(x):
    if x is None:
        return "-"
    if x >= 1:
        return f"{x:.2f}s"
    return f"{x*1e3:.2f}ms"


def dryrun_table(cells):
    rows = ["| arch | shape | mesh | status | bytes/dev (args+tmp) | compile |",
            "|---|---|---|---|---|---|"]
    for c in sorted(cells, key=lambda c: (c["arch"], c["shape"], c["mesh"])):
        if c.get("tag"):
            continue
        if c["status"] == "skipped":
            rows.append(f"| {c['arch']} | {c['shape']} | {c['mesh']} | SKIP (long_500k "
                        f"full-attn) | - | - |")
            continue
        if c["status"] != "ok":
            rows.append(f"| {c['arch']} | {c['shape']} | {c['mesh']} | "
                        f"ERROR: {c.get('error','')[:60]} | - | - |")
            continue
        mem = c.get("memory", {})
        args = mem.get("argument_size_in_bytes", 0) / 2**30
        tmp = mem.get("temp_size_in_bytes", 0) / 2**30
        rows.append(
            f"| {c['arch']} | {c['shape']} | {c['mesh']} | OK | "
            f"{args:.1f}+{tmp:.1f} GiB | {c.get('compile_s','-')}s |")
    return "\n".join(rows)


def roofline_table(cells):
    rows = ["| arch | shape | compute | memory | collective | bottleneck | "
            "MODEL_FLOPS/HLO | step LB |",
            "|---|---|---|---|---|---|---|---|"]
    for c in sorted(cells, key=lambda c: (c["arch"], c["shape"])):
        if c["status"] != "ok" or c["mesh"] != "single" or c.get("tag"):
            continue
        if "compute_s" not in c:
            continue
        rows.append(
            f"| {c['arch']} | {c['shape']} | {fmt_s(c['compute_s'])} | "
            f"{fmt_s(c['memory_s'])} | {fmt_s(c['collective_s'])} | "
            f"{c['bottleneck'].replace('_s','')} | "
            f"{c['useful_flops_ratio']:.2f} | "
            f"{fmt_s(c['step_time_lower_bound_s'])} |")
    return "\n".join(rows)


def comm_table(cells):
    rows = ["| arch | shape | all-reduce | all-gather | reduce-scatter | "
            "all-to-all | permute |",
            "|---|---|---|---|---|---|---|"]
    for c in sorted(cells, key=lambda c: (c["arch"], c["shape"])):
        if c["status"] != "ok" or c["mesh"] != "single" or c.get("tag"):
            continue
        k = c.get("comm_by_kind_probe2", {})
        gb = lambda key: f"{k.get(key,0)/2**20:.1f}M" if k.get(key) else "-"
        rows.append(
            f"| {c['arch']} | {c['shape']} | {gb('all-reduce')} | "
            f"{gb('all-gather')} | {gb('reduce-scatter')} | "
            f"{gb('all-to-all')} | {gb('collective-permute')} |")
    return "\n".join(rows)


def summarize(cells):
    ok = [c for c in cells if c["status"] == "ok" and not c.get("tag")]
    skip = [c for c in cells if c["status"] == "skipped"]
    err = [c for c in cells if c["status"] == "error"]
    single = [c for c in ok if c["mesh"] == "single"]
    multi = [c for c in ok if c["mesh"] == "multi"]
    return (f"{len(ok)} compiled OK ({len(single)} single-pod 16x16=256 chips, "
            f"{len(multi)} multi-pod 2x16x16=512 chips), {len(skip)} skipped "
            f"(documented long_500k full-attention skips), {len(err)} errors")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dryrun", default="results/dryrun")
    args = ap.parse_args()
    cells = load(args.dryrun)
    print("## Summary\n")
    print(summarize(cells), "\n")
    print("## Dry-run table\n")
    print(dryrun_table(cells), "\n")
    print("## Roofline (single-pod, per device)\n")
    print(roofline_table(cells), "\n")
    print("## Collective breakdown (2-period probe, bytes)\n")
    print(comm_table(cells))


if __name__ == "__main__":
    main()
