"""Step builders: sharded train / prefill / decode steps for any arch.

Everything here works on abstract inputs (ShapeDtypeStruct with attached
NamedShardings) so the dry-run can .lower().compile() with zero
allocation; the same builders drive real training/serving.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro import sharding as shd
from repro.config import (
    ArchConfig,
    ModelConfig,
    OptimizerConfig,
    ParallelConfig,
    ShapeConfig,
)
from repro.models import api, meta
from repro.optim import adamw_init, adamw_update, clip_by_global_norm, cosine_warmup


@dataclasses.dataclass
class Plan:
    """A fully-resolved (arch x shape x mesh) execution plan."""

    arch: ArchConfig
    shape: ShapeConfig
    parallel: ParallelConfig
    mesh: object
    rules: list

    @property
    def model(self) -> ModelConfig:
        return self.arch.model

    def ns(self, spec) -> NamedSharding:
        return NamedSharding(self.mesh, spec)

    def batch_spec(self, b: int) -> P:
        axes = self.parallel.batch_axes
        n = 1
        for a in axes:
            n *= dict(zip(self.parallel.mesh_axes, self.parallel.mesh_shape))[a]
        return P(axes) if b % n == 0 else P()


def make_plan(arch: ArchConfig, shape: ShapeConfig, mesh, parallel: ParallelConfig):
    rules = shd.default_rules(
        fsdp=arch.fsdp,
        batch_axes=parallel.batch_axes,
        fsdp_axes=parallel.batch_axes if arch.fsdp else ("data",),
    )
    return Plan(arch=arch, shape=shape, parallel=parallel, mesh=mesh, rules=rules)


# ----------------------------------------------------------- shardings
def param_shardings(plan: Plan):
    tpl = api.template(plan.model)
    specs = meta.param_specs(tpl, plan.rules, dict(plan.mesh.shape))
    return jax.tree.map(plan.ns, specs)


def abstract_params(plan: Plan):
    tpl = api.template(plan.model)
    sds = meta.abstract_params(tpl)
    sh = param_shardings(plan)
    return jax.tree.map(
        lambda a, s: jax.ShapeDtypeStruct(a.shape, a.dtype, sharding=s), sds, sh
    )


def abstract_opt_state(plan: Plan, opt: OptimizerConfig):
    ps = abstract_params(plan)
    mdt = jnp.dtype(opt.moment_dtype)
    mom = jax.tree.map(
        lambda a: jax.ShapeDtypeStruct(a.shape, mdt, sharding=a.sharding), ps
    )
    return {
        "m": mom,
        "v": mom,
        "step": jax.ShapeDtypeStruct((), jnp.int32, sharding=plan.ns(P())),
    }


def abstract_batch(plan: Plan):
    m, s = plan.model, plan.shape
    b = api.make_batch_shapes(m, s.global_batch, s.seq_len)
    bspec = plan.batch_spec(s.global_batch)

    def att(a, name):
        spec = P(*bspec, *([None] * (len(a.shape) - len(bspec))))
        return jax.ShapeDtypeStruct(a.shape, a.dtype, sharding=plan.ns(spec))

    return {k: att(v, k) for k, v in b.items()}


def cache_len_for(plan: Plan) -> int:
    """KV length: seq plus the VLM stub prefix rows (vision tokens live
    in the same decoder cache)."""
    m = plan.model
    extra = m.frontend_len if (m.frontend != "none" and not m.n_encoder_layers) else 0
    return plan.shape.seq_len + extra


def abstract_cache(plan: Plan):
    m, s = plan.model, plan.shape
    cache = jax.eval_shape(
        lambda: api.init_cache(m, s.global_batch, cache_len_for(plan))
    )
    axes_tree = api.cache_axes(plan.model)
    sizes = dict(plan.mesh.shape)
    is_axes = lambda a: isinstance(a, tuple) and all(
        isinstance(e, str) or e is None for e in a
    )

    def mk(axes, arr):
        spec = shd.resolve(axes, plan.rules, sizes, shape=arr.shape)
        return jax.ShapeDtypeStruct(arr.shape, arr.dtype, sharding=plan.ns(spec))

    return jax.tree.map(mk, axes_tree, cache, is_leaf=is_axes)


def cache_shardings(plan: Plan):
    return jax.tree.map(lambda a: a.sharding, abstract_cache(plan))


# ----------------------------------------------------------- step fns
def build_train_step(plan: Plan, opt: OptimizerConfig):
    m = plan.model
    accum = plan.parallel.grad_accum

    def loss_fn(params, batch):
        return api.loss_fn(params, batch, m)

    def train_step(params, opt_state, batch):
        if accum > 1:
            def micro(carry, mb):
                g_acc, = carry
                l, g = jax.value_and_grad(loss_fn)(params, mb)
                return (jax.tree.map(jnp.add, g_acc, g),), l

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            mbs = jax.tree.map(
                lambda x: x.reshape((accum, x.shape[0] // accum) + x.shape[1:]),
                batch,
            )
            (gsum,), losses = jax.lax.scan(micro, (zeros,), mbs)
            grads = jax.tree.map(lambda g: g / accum, gsum)
            loss = losses.mean()
        else:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        grads, gnorm = clip_by_global_norm(grads, opt.grad_clip)
        lr = cosine_warmup(opt_state["step"], opt.lr, opt.warmup_steps, opt.total_steps)
        params, opt_state = adamw_update(params, grads, opt_state, opt, lr)
        return params, opt_state, {"loss": loss, "gnorm": gnorm, "lr": lr}

    return train_step


def build_prefill_step(plan: Plan):
    m = plan.model
    clen = cache_len_for(plan)

    def prefill_step(params, batch):
        return api.prefill(params, batch, m, cache_len=clen)

    return prefill_step


def build_decode_step(plan: Plan):
    m = plan.model

    def serve_step(params, token, caches, pos):
        return api.decode_step(params, token, caches, pos, m)

    return serve_step


def abstract_decode_inputs(plan: Plan):
    b = plan.shape.global_batch
    tok = jax.ShapeDtypeStruct(
        (b, 1), jnp.int32,
        sharding=plan.ns(P(*plan.batch_spec(b), None)),
    )
    pos = jax.ShapeDtypeStruct((), jnp.int32, sharding=plan.ns(P()))
    return tok, abstract_cache(plan), pos


# ------------------------------------------------------------ lowering
def lower_cell(plan: Plan, opt: OptimizerConfig | None = None):
    """Lower the cell's step function with abstract inputs.  Returns
    (lowered, kind)."""
    opt = opt or OptimizerConfig(moment_dtype=plan.arch.moment_dtype)
    kind = plan.shape.kind
    with shd.sharding_ctx(plan.mesh, plan.rules):
        if kind == "train":
            fn = build_train_step(plan, opt)
            args = (abstract_params(plan), abstract_opt_state(plan, opt),
                    abstract_batch(plan))
            lowered = jax.jit(fn, donate_argnums=(0, 1)).lower(*args)
        elif kind == "prefill":
            fn = build_prefill_step(plan)
            args = (abstract_params(plan), abstract_batch(plan))
            lowered = jax.jit(fn).lower(*args)
        else:  # decode
            fn = build_decode_step(plan)
            tok, cache, pos = abstract_decode_inputs(plan)
            lowered = jax.jit(fn, donate_argnums=(2,)).lower(
                abstract_params(plan), tok, cache, pos
            )
    return lowered, kind
