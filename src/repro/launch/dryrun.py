import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: .lower().compile() every (arch x shape x mesh) cell.

The two lines above MUST run before any jax import (jax locks the device
count at first init); 512 placeholder host devices back both the 16x16
single-pod mesh (first 256) and the 2x16x16 multi-pod mesh.

Per cell we record compiled memory analysis (proves fit), cost analysis
(FLOPs/bytes for the roofline), and the parsed collective schedule.

  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-1.5b \
      --shape train_4k --mesh single --out results/dryrun
  PYTHONPATH=src python -m repro.launch.dryrun --all --out results/dryrun
"""

import argparse  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402


def run_cell(arch_name: str, shape_name: str, mesh_kind: str,
             dispatch: str | None = None, remat: str | None = None,
             extra_tag: str = "", probes: bool | None = None):
    import dataclasses

    import jax

    from repro import configs
    from repro.config import SHAPES, ParallelConfig
    from repro.launch import roofline
    from repro.launch.mesh import make_production_mesh
    from repro.launch.steps import lower_cell, make_plan

    arch = configs.get_config(arch_name)
    shape = SHAPES[shape_name]
    if shape_name not in arch.shapes:
        return {"arch": arch_name, "shape": shape_name, "mesh": mesh_kind,
                "status": "skipped", "note": arch.skip_notes}

    multi = mesh_kind == "multi"
    mesh = make_production_mesh(multi_pod=multi)
    n_dev = mesh.devices.size
    par = ParallelConfig(
        mesh_shape=(2, 16, 16) if multi else (16, 16),
        mesh_axes=("pod", "data", "model") if multi else ("data", "model"),
        fsdp=arch.fsdp,
    )
    model = arch.model
    if dispatch and model.moe is not None:
        model = dataclasses.replace(
            model, moe=dataclasses.replace(model.moe, dispatch=dispatch)
        )
    if remat:
        model = dataclasses.replace(model, remat=remat)
    arch = dataclasses.replace(arch, model=model)

    # ---- pass A: the REQUIRED dry-run — full model, scanned layers.
    # Proves lower+compile succeed on the production mesh and yields the
    # memory analysis.  (cost_analysis of this pass under-counts loop
    # bodies — see pass B.)
    plan = make_plan(arch, shape, mesh, par)
    t0 = time.time()
    lowered, kind = lower_cell(plan)
    t1 = time.time()
    compiled = lowered.compile()
    t2 = time.time()
    res = roofline.analyze(compiled, n_dev)
    res["full_pass_raw"] = {
        "flops_per_device": res.pop("flops_per_device"),
        "bytes_per_device": res.pop("bytes_per_device"),
        "comm_bytes_per_device": res.pop("comm_bytes_per_device"),
        "note": "scanned-loop HLO: loop bodies counted once by "
                "cost_analysis; roofline uses the probe extrapolation",
    }
    print(compiled.memory_analysis())
    del lowered, compiled

    if probes is None:
        probes = mesh_kind == "single"  # roofline table is single-pod only
    if not probes:
        res.update(
            arch=arch_name, shape=shape_name, mesh=mesh_kind, kind=kind,
            status="ok", lower_s=round(t1 - t0, 2),
            compile_s=round(t2 - t1, 2),
            note="multi-pod proof pass (no probe extrapolation)",
        )
        for k in ("compute_s", "memory_s", "collective_s", "bottleneck",
                  "step_time_lower_bound_s"):
            res.pop(k, None)
        if extra_tag:
            res["tag"] = extra_tag
        return res

    # ---- pass B: probe compiles with 1 and 2 periods, all loops
    # unrolled; linear extrapolation recovers exact per-step counts:
    #   f(n) = f(1) + (n-1) * (f(2) - f(1))
    per = len(model.layer_pattern)
    n_periods_full = model.n_layers // per
    # Probe attention chunk: cost totals are chunk-invariant
    # (nq*nk*cq*ck == S^2 either way) but tracing/compile time is not —
    # cap the unrolled grid at 4x4 blocks.
    probe_chunk = max(model.attn_chunk, shape.seq_len // 4)
    probe_res = {}
    for k in (1, 2):
        pm = dataclasses.replace(
            model,
            n_layers=k * per,
            n_encoder_layers=k if model.n_encoder_layers else 0,
            scan_layers=False,
            attn_chunk=probe_chunk,
        )
        pa = dataclasses.replace(arch, model=pm)
        pplan = make_plan(pa, shape, mesh, par)
        lw, _ = lower_cell(pplan)
        probe_res[k] = roofline.analyze(lw.compile(), n_dev)

    def extrap(key):
        f1, f2 = probe_res[1][key], probe_res[2][key]
        return f1 + (n_periods_full - 1) * (f2 - f1)

    flops = extrap("flops_per_device")
    byts = extrap("bytes_per_device")
    comm = extrap("comm_bytes_per_device")
    terms = {
        "compute_s": flops / roofline.PEAK_FLOPS,
        "memory_s": byts / roofline.HBM_BW,
        "collective_s": comm / roofline.LINK_BW,
    }
    mf = roofline.model_flops(arch, shape)
    res.update(
        arch=arch_name,
        shape=shape_name,
        mesh=mesh_kind,
        kind=kind,
        status="ok",
        lower_s=round(t1 - t0, 2),
        compile_s=round(t2 - t1, 2),
        flops_per_device=flops,
        bytes_per_device=byts,
        comm_bytes_per_device=comm,
        comm_by_kind_probe2=probe_res[2]["comm_by_kind"],
        **terms,
        bottleneck=max(terms, key=terms.get),
        step_time_lower_bound_s=max(terms.values()),
        model_flops_global=mf,
        model_flops_per_device=mf / n_dev,
        useful_flops_ratio=(mf / n_dev) / max(flops, 1.0),
    )
    if extra_tag:
        res["tag"] = extra_tag
    return res


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--dispatch", default=None)
    ap.add_argument("--remat", default=None)
    ap.add_argument("--tag", default="")
    ap.add_argument("--out", default="results/dryrun")
    args = ap.parse_args()

    from repro import configs
    from repro.config import SHAPES

    os.makedirs(args.out, exist_ok=True)
    cells = []
    if args.all:
        # single-pod cells first (they carry the roofline table), then
        # the multi-pod proof passes.
        for mk in ("single", "multi"):
            for a in configs.all_archs():
                for s in SHAPES:
                    cells.append((a, s, mk))
    else:
        cells = [(args.arch, args.shape, args.mesh)]

    failures = 0
    for a, s, mk in cells:
        tag = f"_{args.tag}" if args.tag else ""
        path = os.path.join(args.out, f"{a}__{s}__{mk}{tag}.json")
        if os.path.exists(path) and args.all:
            print(f"[dryrun] {a} x {s} x {mk}: cached")
            continue
        print(f"[dryrun] {a} x {s} x {mk} ...", flush=True)
        t0 = time.time()
        try:
            res = run_cell(a, s, mk, dispatch=args.dispatch, remat=args.remat,
                           extra_tag=args.tag)
        except Exception as e:
            failures += 1
            res = {"arch": a, "shape": s, "mesh": mk, "status": "error",
                   "error": f"{type(e).__name__}: {e}",
                   "trace": traceback.format_exc()[-2000:]}
            print(f"[dryrun] FAILED {a} x {s} x {mk}: {e}")
        res["wall_s"] = round(time.time() - t0, 2)
        with open(path, "w") as f:
            json.dump(res, f, indent=1)
        if res["status"] == "ok" and "compute_s" in res:
            print(
                f"[dryrun] OK {a} x {s} x {mk}: compute={res['compute_s']:.4f}s "
                f"memory={res['memory_s']:.4f}s coll={res['collective_s']:.4f}s "
                f"bottleneck={res['bottleneck']} (compile {res['compile_s']}s)",
                flush=True,
            )
        elif res["status"] == "ok":
            print(f"[dryrun] OK {a} x {s} x {mk}: multi-pod proof "
                  f"(compile {res['compile_s']}s)", flush=True)
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
