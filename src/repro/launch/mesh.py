"""Production meshes.  Importing this module never touches jax device
state — meshes are built inside functions only."""

from __future__ import annotations

import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single pod (256 chips) or 2x16x16 multi-pod (512 chips)."""
    import jax

    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()[:n]
    assert len(devices) == n, (
        f"need {n} devices, have {len(jax.devices())} — the dry-run sets "
        "XLA_FLAGS=--xla_force_host_platform_device_count=512 first"
    )
    from repro.compat import mesh_axis_type_kwargs

    return jax.make_mesh(
        shape, axes, devices=devices, **mesh_axis_type_kwargs(len(axes))
    )


def make_mesh(shape, axes):
    """Arbitrary mesh over the first prod(shape) devices."""
    import jax

    n = int(np.prod(shape))
    devices = jax.devices()[:n]
    assert len(devices) == n, (n, len(jax.devices()))
    from repro.compat import mesh_axis_type_kwargs

    return jax.make_mesh(
        tuple(shape), tuple(axes), devices=devices,
        **mesh_axis_type_kwargs(len(axes))
    )
