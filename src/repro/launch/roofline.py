"""Roofline analysis from compiled dry-run artifacts (TPU v5e targets).

Three terms per (arch x shape x mesh) cell, all in seconds-per-step:

  compute    = per_device_FLOPs / peak_flops
  memory     = per_device_bytes / hbm_bw
  collective = per_device_comm_bytes / link_bw

FLOPs/bytes come from ``compiled.cost_analysis()`` (per-partition
program).  Collective bytes are NOT in cost_analysis: we parse the
optimized HLO and sum shape sizes of every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute (all-reduce counts 2x
for the ring reduce+broadcast halves; others 1x of the largest buffer
on the op line — gathered output / full input respectively).
"""

from __future__ import annotations

import math
import re

# TPU v5e per-chip constants (assignment-specified)
PEAK_FLOPS = 197e12  # bf16
HBM_BW = 819e9  # B/s
LINK_BW = 50e9  # B/s per ICI link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_COLL_RE = re.compile(
    r"\b(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\("
)


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def collective_bytes(hlo_text: str):
    """Per-device communicated bytes (approx) + per-op-kind breakdown."""
    total = 0
    by_kind: dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m or "-done" in line.split("=")[0]:
            continue
        kind = m.group(1)
        sizes = [_shape_bytes(d, s) for d, s in _SHAPE_RE.findall(line)]
        if not sizes:
            continue
        size = max(sizes)
        moved = 2 * size if kind == "all-reduce" else size
        total += moved
        by_kind[kind] = by_kind.get(kind, 0) + moved
    return total, by_kind


def analyze(compiled, n_devices: int):
    """Extract roofline terms from a compiled executable."""
    ca = {}
    try:
        c = compiled.cost_analysis()
        if isinstance(c, (list, tuple)):
            c = c[0]
        ca = dict(c or {})
    except Exception as e:  # backend without cost analysis
        ca = {"error": str(e)}
    flops = float(ca.get("flops", 0.0))
    bytes_accessed = float(ca.get("bytes accessed", 0.0))

    mem = {}
    try:
        ma = compiled.memory_analysis()
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "alias_size_in_bytes",
                  "generated_code_size_in_bytes"):
            v = getattr(ma, k, None)
            if v is not None:
                mem[k] = int(v)
    except Exception as e:
        mem = {"error": str(e)}

    text = compiled.as_text()
    comm, by_kind = collective_bytes(text)

    t_compute = flops / PEAK_FLOPS
    t_memory = bytes_accessed / HBM_BW
    t_coll = comm / LINK_BW
    terms = {"compute_s": t_compute, "memory_s": t_memory, "collective_s": t_coll}
    bottleneck = max(terms, key=terms.get)
    return {
        "n_devices": n_devices,
        "flops_per_device": flops,
        "bytes_per_device": bytes_accessed,
        "comm_bytes_per_device": comm,
        "comm_by_kind": by_kind,
        "memory": mem,
        **terms,
        "bottleneck": bottleneck,
        "step_time_lower_bound_s": max(terms.values()),
    }


# --------------------------------------------------------- model flops
def active_params(model_cfg, template) -> tuple[int, int]:
    """(total_params, active_params_per_token) — MoE experts count k/E."""
    import jax

    from repro.models.meta import is_meta

    total = 0
    active = 0.0
    mo = model_cfg.moe
    flat = jax.tree_util.tree_flatten_with_path(
        template, is_leaf=is_meta
    )[0]
    for path, m in flat:
        size = math.prod(m.shape)
        total += size
        keys = [str(getattr(p, "key", "")) for p in path]
        if mo is not None and "moe" in keys and any(
            k in ("wg", "wu", "wd") for k in keys
        ) and "shared" not in keys:
            active += size * (mo.top_k / mo.n_experts)
        else:
            active += size
    return total, int(active)


def model_flops(arch_cfg, shape_cfg) -> float:
    """Useful-math FLOPs per step (global): 6*N_active*D train, 2*N*D
    inference forward, + causal attention term."""
    from repro.models import api

    m = arch_cfg.model
    tpl = api.template(m)
    total, active = active_params(m, tpl)
    b, s = shape_cfg.global_batch, shape_cfg.seq_len
    n_attn = sum(
        1 for sl in m.layer_pattern if sl.mixer in ("attn", "mla")
    ) * m.n_periods if not api.is_encdec(m) else m.n_layers * 2 + m.n_encoder_layers

    if shape_cfg.kind == "train":
        tokens = b * s
        flops = 6.0 * active * tokens
        # causal attention: 2 matmuls * 2 (fwd+2bwd=3x fwd cost => *3 on 2*)
        flops += 3.0 * 2.0 * 2.0 * n_attn * m.n_heads * m.dh * (s * s / 2) * b
    elif shape_cfg.kind == "prefill":
        tokens = b * s
        flops = 2.0 * active * tokens
        flops += 2.0 * 2.0 * n_attn * m.n_heads * m.dh * (s * s / 2) * b
    else:  # decode: one token per sequence against an s-long cache
        tokens = b
        flops = 2.0 * active * tokens
        flops += 2.0 * 2.0 * n_attn * m.n_heads * m.dh * s * b
    return flops
