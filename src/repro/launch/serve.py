"""Serving launcher: batched prefill + decode with sort-based sampling.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b --smoke \
      --requests 4 --gen 16

Implements a minimal batched server loop: a request queue is packed
into a fixed batch, prefilled once, then decoded token-by-token.  The
sampler's top-k runs on the paper's partial deterministic sample sort.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np


def sample_topk(logits, k, temperature, rng_key, cfg, check="off"):
    """Per-row top-k sampling via the paper's partial sort (vocab-scale).

    ``check`` ('off'|'bounds'|'full') turns on the sort's runtime
    invariants (DESIGN.md §11) for every sampling step.
    """
    from repro.core import partial_sort
    from repro.core.sort_config import SortConfig

    scfg = SortConfig(tile=4096, s=64, direct_max=8192, impl="xla",
                      check=check)
    if k <= 1 or temperature <= 0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    outs = []
    for b in range(logits.shape[0]):
        vals, idx = partial_sort.topk(logits[b], k, scfg)
        p = jax.nn.softmax(vals.astype(jnp.float32) / temperature)
        choice = jax.random.choice(jax.random.fold_in(rng_key, b), k, p=p)
        outs.append(idx[choice])
    return jnp.stack(outs).astype(jnp.int32)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--topk", type=int, default=8)
    ap.add_argument("--temperature", type=float, default=0.8)
    ap.add_argument("--check", choices=["off", "bounds", "full"],
                    default="off",
                    help="runtime sort invariants for the sampler "
                         "(DESIGN.md §11): 'bounds' verifies the capacity "
                         "bound, 'full' adds permutation+order checks")
    args = ap.parse_args()

    from repro import configs
    from repro.models import api, meta

    cfg = configs.get_smoke(args.arch) if args.smoke else configs.get_config(args.arch).model
    tpl = api.template(cfg)
    params = meta.init_params(tpl, jax.random.PRNGKey(0))
    print(f"[serve] {cfg.name}: {meta.count_params(tpl)/1e6:.1f}M params")

    rng = np.random.default_rng(0)
    b, s = args.requests, args.prompt_len
    cache_len = s + args.gen
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (b, s)), jnp.int32)}
    if api.is_encdec(cfg):
        batch["enc_frames"] = jnp.asarray(
            rng.normal(size=(b, cfg.encoder_positions, cfg.d_model)).astype(np.float32)
        ).astype(cfg.dtype)
    elif cfg.frontend != "none" and cfg.frontend_len:
        batch["prefix_embeds"] = jnp.asarray(
            rng.normal(size=(b, cfg.frontend_len, cfg.d_model)).astype(np.float32)
        ).astype(cfg.dtype)

    prefill = jax.jit(lambda p, bt: api.prefill(p, bt, cfg, cache_len))
    step = jax.jit(lambda p, t, c, pos: api.decode_step(p, t, c, pos, cfg))

    t0 = time.perf_counter()
    logits, caches = prefill(params, batch)
    jax.block_until_ready(logits)
    t_prefill = time.perf_counter() - t0

    key = jax.random.PRNGKey(1)
    tok = sample_topk(
        logits, args.topk, args.temperature, key, cfg, check=args.check
    )[:, None]
    out_tokens = [tok]
    t0 = time.perf_counter()
    for i in range(args.gen - 1):
        logits, caches = step(params, tok, caches, jnp.int32(s + i))
        tok = sample_topk(
            logits, args.topk, args.temperature, jax.random.fold_in(key, i),
            cfg, check=args.check,
        )[:, None]
        out_tokens.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.perf_counter() - t0

    gen = np.concatenate([np.asarray(t) for t in out_tokens], axis=1)
    assert (gen >= 0).all() and (gen < cfg.padded_vocab).all()
    print(f"[serve] prefill {b}x{s}: {t_prefill*1e3:.1f} ms; "
          f"decode {args.gen-1} steps: {t_decode*1e3/(max(args.gen-1,1)):.1f} ms/tok")
    print(f"[serve] sample generations (token ids):\n{gen[:, :12]}")


if __name__ == "__main__":
    main()
