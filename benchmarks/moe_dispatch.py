"""Beyond-paper: MoE token-dispatch throughput — the paper's bucket
machinery (sample_sort) vs vendor argsort vs GShard one-hot einsum."""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import timeit
from repro.config import LayerSlot, ModelConfig, MoEConfig
from repro.models import moe as MOE
from repro.models.meta import init_params


def run(tokens=16384, e=128, k=8, d=256, repeats=3):
    rows = []
    base = ModelConfig(
        name="bench", n_layers=1, d_model=d, n_heads=4, n_kv_heads=4,
        d_ff=4 * d, vocab=1024, layer_pattern=(LayerSlot("attn", "moe"),),
        moe=MoEConfig(n_experts=e, top_k=k, d_ff_expert=d // 2),
        param_dtype="float32", dtype="float32",
    )
    p = init_params(MOE.moe_template(base), jax.random.PRNGKey(0))
    rng = np.random.default_rng(4)
    x = jnp.asarray(rng.normal(size=(1, tokens, d)).astype(np.float32))
    outs = {}
    for disp in ("sample_sort", "xla_sort", "onehot"):
        cfg = dataclasses.replace(
            base, moe=dataclasses.replace(base.moe, dispatch=disp)
        )
        fn = jax.jit(lambda pp, xx, c=cfg: MOE.moe_apply(pp, xx, c)[0])
        t = timeit(fn, p, x, repeats=repeats)
        outs[disp] = (t, np.asarray(fn(p, x)))
        rows.append(dict(
            name=f"moe_dispatch/{disp}", us_per_call=t * 1e6,
            derived=f"tokens={tokens} E={e} k={k} "
                    f"{tokens*k/t/1e6:.2f}M assignments/s"))
    a, b = outs["sample_sort"][1], outs["onehot"][1]
    rows.append(dict(name="moe_dispatch/impl_agreement", us_per_call=0.0,
                     derived=f"max|Δ|={np.abs(a-b).max():.2e}"))
    return rows
