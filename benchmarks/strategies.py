"""--suite strategies: local-sort strategy comparison (DESIGN.md §8).

End-to-end plan-driven sorts (``sort_planned``, jit static plan) with
the ONLY difference being ``SortConfig.strategy``, crossed with the
input distributions the surveys say discriminate between the
algorithms: uniform (radix home turf on narrow keys), nearly-sorted
(merge home turf), skewed and all-dup (low digit entropy — bitonic /
lax.sort robustness).  All on the CPU/xla proxy of this container; the
bitonic rows keep the unchanged ``lax.sort`` two-key stand-in, so the
speedup columns measure exactly what the strategy dispatch buys.

The acceptance rows for ISSUE 6 are the explicitly named
``radix_vs_bitonic_uniform`` (int32, n=2^20) and
``merge_vs_bitonic_nearly_sorted`` entries.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import make_distribution, timeit
from repro.core import bucket_sort as bs
from repro.core.sort_config import SortConfig

STRATEGIES = ("bitonic", "radix", "merge")
DISTS = ("uniform", "nearly-sorted", "skewed", "all-dup")


def run(n=1048576, repeats=3):
    rng = np.random.default_rng(11)
    rows = []
    for dist in DISTS:
        x = jnp.asarray(make_distribution(dist, n, rng))
        us = {}
        for st in STRATEGIES:
            cfg = SortConfig(impl="xla", strategy=st)
            plan = bs.resolve_plan(n, jnp.int32, cfg)
            t = timeit(
                lambda a, p=plan: bs.sort_planned(a, p), x, repeats=repeats
            )
            us[st] = t * 1e6
            rows.append(dict(
                name=f"strategies/{dist}_{st}",
                us_per_call=us[st],
                derived=f"int32 n={n} xla end-to-end",
            ))
        for st in ("radix", "merge"):
            rows.append(dict(
                name=f"strategies/{dist}_{st}_speedup_vs_bitonic",
                us_per_call=us[st],
                derived=f"{us['bitonic'] / max(us[st], 1e-9):.2f}x vs "
                        f"bitonic ({dist}, n={n})",
            ))
    # The ISSUE 6 acceptance rows, named explicitly.
    def _get(nm):
        return next(r for r in rows if r["name"] == f"strategies/{nm}")

    ub, ur = _get("uniform_bitonic"), _get("uniform_radix")
    nb, nm_ = _get("nearly-sorted_bitonic"), _get("nearly-sorted_merge")
    rows.append(dict(
        name="strategies/radix_vs_bitonic_uniform",
        us_per_call=ur["us_per_call"],
        derived=f"{ub['us_per_call'] / max(ur['us_per_call'], 1e-9):.2f}x "
                f"faster than bitonic (int32 uniform, n={n})",
    ))
    rows.append(dict(
        name="strategies/merge_vs_bitonic_nearly_sorted",
        us_per_call=nm_["us_per_call"],
        derived=f"{nb['us_per_call'] / max(nm_['us_per_call'], 1e-9):.2f}x "
                f"faster than bitonic (int32 nearly-sorted, n={n})",
    ))
    return rows
