"""Fig. 3 analogue: total runtime vs sample size s at fixed n (C4).

The paper finds s=64 optimal on GTX285: bucket-sort time falls with s,
sampling overhead (steps 3-7) grows with s.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import timeit
from repro.core import bucket_sort
from repro.core.sort_config import SortConfig


def run(n=524288, svals=(8, 16, 32, 64, 128), repeats=3):
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.integers(-(2**31), 2**31 - 1, n).astype(np.int32))
    rows = []
    best = (None, np.inf)
    for s in svals:
        cfg = SortConfig(tile=4096, s=s, direct_max=8192, impl="xla")
        t = timeit(lambda a: bucket_sort.sort(a, cfg), x, repeats=repeats)
        if t < best[1]:
            best = (s, t)
        rows.append(dict(name=f"sample_size_sweep/s={s}", us_per_call=t * 1e6,
                         derived=f"n={n}"))
    rows.append(dict(name="sample_size_sweep/best_s", us_per_call=best[1] * 1e6,
                     derived=f"s={best[0]} (paper: 64 on GTX285)"))
    return rows
