"""Fig. 4/6/7 analogue: sorting rate vs n, ours vs the paper's baselines.

The paper's C1/C6 claims: near-linear runtime growth (fixed sorting
rate) and parity with randomized sample sort on uniform data.  CPU
wall-times here are proxies (TPU is the target); the fixed-rate SHAPE
of the curve is the reproduced claim.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import timeit
from repro.core import baselines, bucket_sort
from repro.core.sort_config import SortConfig

CFG = SortConfig(tile=4096, s=64, direct_max=8192, impl="xla")


def run(sizes=(65536, 262144, 1048576), repeats=3):
    rng = np.random.default_rng(0)
    rows = []
    for n in sizes:
        x = jnp.asarray(rng.integers(-(2**31), 2**31 - 1, n).astype(np.int32))
        t_ours = timeit(lambda a: bucket_sort.sort(a, CFG), x, repeats=repeats)
        t_xla = timeit(lambda a: baselines.xla_sort(a)[0], x, repeats=repeats)
        t_merge = timeit(lambda a: baselines.merge_sort(a, CFG)[0], x, repeats=repeats)
        key = jax.random.PRNGKey(0)
        t_rand = timeit(
            lambda a: baselines.randomized_sample_sort(a, key, CFG)[0], x,
            repeats=repeats,
        )
        rate = n / t_ours / 1e6
        rows.append(
            dict(name=f"sort_throughput/n={n}", us_per_call=t_ours * 1e6,
                 derived=f"rate={rate:.2f}Mkeys/s xla={t_xla*1e6:.0f}us "
                         f"merge={t_merge*1e6:.0f}us rand={t_rand*1e6:.0f}us")
        )
    # fixed sorting rate check (C1): rate ratio across 16x size range
    r0 = sizes[0] / rows[0]["us_per_call"]
    r2 = sizes[-1] / rows[-1]["us_per_call"]
    rows.append(dict(name="sort_throughput/rate_ratio_largest_vs_smallest",
                     us_per_call=0.0, derived=f"{r2 / r0:.3f} (~1.0 == linear)"))
    return rows
