"""Fig. 5 analogue: per-step timing of Algorithm 1 (C3), plus the
baseline-vs-fused deltas of the row-blocked pipeline (DESIGN.md §3-§4).

The paper observes: local sort (step 2) + sublist sort (step 9)
dominate; deterministic-sampling overhead (steps 3-7) is small; the
relocation (step 8) is cheap because it is one coalesced pass.

On top of the per-step rows this emits A/B rows for the two hot spots
this port optimizes:
  * step 2 local sort — per-tile (block_rows=1) vs row-blocked Pallas
    kernel, both interpret-mode (the container has no TPU);
  * steps 8/9 relocation + compaction — legacy scatter formulation vs
    the scatter-free gather formulation, on the xla path.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import timeit
from repro.core import bucket_sort as bs
from repro.core.sort_config import SortConfig
from repro.kernels import ops

CFG = SortConfig(tile=4096, s=64, direct_max=8192, impl="xla")


def run(n=1048576, repeats=3, pallas_compare=True):
    rng = np.random.default_rng(2)
    x = rng.integers(-(2**31), 2**31 - 1, n).astype(np.int32)
    u = ops.to_sortable(jnp.asarray(x))
    # All geometry comes off the RESOLVED plan (the planner/executor
    # split, DESIGN.md §7) — the benchmark no longer re-derives it.
    full_plan = bs.resolve_plan(n, jnp.int32, CFG)
    root = full_plan.root
    assert root.kind == "bucket", "step breakdown needs a bucket round"
    t, sper = root.tile, root.s
    lp, m = root.lp, root.m
    s_round, cap = root.s_round, root.cap
    r = 1

    # --- Per-step rows (Fig. 5), on the default fused path. -------------
    @jax.jit
    def local_sort(u):
        v = jnp.arange(lp, dtype=jnp.int32)
        return ops.sort_tiles_sample(
            u.reshape(m, t), v.reshape(m, t), num_samples=sper, impl="xla"
        )

    tk, tv, sampk, sampv = jax.block_until_ready(local_sort(u))

    @jax.jit
    def sample_sort(sampk, sampv):
        # internal canonical entries take tuples of key words (one word
        # for int32 keys); unwrap on the way out
        skw, ssv, _ = bs._sort_rows(
            (sampk.reshape(1, m * sper),), sampv.reshape(1, m * sper),
            CFG, 2 * lp, None,
        )
        return skw[0], ssv

    ssk, ssv = jax.block_until_ready(sample_sort(sampk, sampv))

    def splitters(ssk, ssv):
        sp_idx = (jnp.arange(1, s_round, dtype=jnp.int32) * (m * sper)) // s_round
        spk = jnp.repeat(ssk[:, sp_idx], m, axis=0)
        spv = jnp.repeat(ssv[:, sp_idx], m, axis=0)
        return spk, spv

    @jax.jit
    def ranks_fn(tk, tv, ssk, ssv):
        spk, spv = splitters(ssk, ssv)
        return ops.splitter_partition(tk, tv, spk, spv, impl="xla")

    ranks, counts2 = jax.block_until_ready(ranks_fn(tk, tv, ssk, ssv))

    @jax.jit
    def full(u):
        return bs._sort_canonical((u,), full_plan)

    rows = []
    t_local = timeit(local_sort, u, repeats=repeats)
    t_samp = timeit(sample_sort, sampk, sampv, repeats=repeats)
    t_rank = timeit(ranks_fn, tk, tv, ssk, ssv, repeats=repeats)
    t_full = timeit(full, u, repeats=repeats)
    rest = max(t_full - t_local - t_samp - t_rank, 0.0)
    for name, tt in [
        ("step2-3_local_sort_fused_sampling", t_local),
        ("step4-5_sample_sort", t_samp),
        ("step6-7_splitter_partition", t_rank),
        ("steps8-9_relocate_and_bucket_sort", rest),
        ("total", t_full),
    ]:
        frac = tt / t_full if t_full else 0
        rows.append(dict(name=f"step_breakdown/{name}", us_per_call=tt * 1e6,
                         derived=f"{100*frac:.1f}% of total (n={n})"))
    overhead = (t_samp + t_rank) / t_full
    rows.append(dict(
        name="step_breakdown/sampling_overhead_fraction", us_per_call=0.0,
        derived=f"{100*overhead:.1f}% (paper C3: small)"))

    # --- Per-strategy local sort (hybrid dispatch, DESIGN.md §8). -------
    v_st = jnp.arange(lp, dtype=jnp.int32).reshape(m, t)
    uk_st = u.reshape(m, t) if lp == n else jnp.pad(u, (0, lp - n)).reshape(m, t)

    @functools.partial(jax.jit, static_argnames=("st",))
    def strat_sort(uk, v, st):
        return ops.sort_tiles(uk, v, impl="xla", strategy=st)

    st_us: dict[str, float] = {}
    for st in ("bitonic", "radix", "merge"):
        st_us[st] = timeit(lambda a, b, s=st: strat_sort(a, b, s),
                           uk_st, v_st, repeats=repeats)
        rows.append(dict(
            name=f"step_breakdown/step2_local_sort_{st}",
            us_per_call=st_us[st] * 1e6,
            derived=f"strategy={st} (xla), "
                    f"{st_us['bitonic'] / max(st_us[st], 1e-12):.2f}x "
                    f"vs bitonic"))

    # --- A/B: scatter vs gather relocation + compaction (steps 8/9). ----
    starts = jnp.concatenate([jnp.zeros((r * m, 1), jnp.int32), ranks], axis=1)
    counts = counts2.reshape(r, m, s_round)
    tile_off = jnp.cumsum(counts, axis=1) - counts
    totals = counts.sum(axis=1)

    @jax.jit
    def reloc_scatter(tk, tv, ranks, starts, tile_off):
        bkw, bv = bs._relocate_scatter(
            (tk,), tv, ranks, starts, tile_off, r, m, s_round, t, cap, 2 * lp)
        return bkw[0], bv

    @jax.jit
    def reloc_gather(tk, tv, starts, tile_off, totals):
        bkw, bv = bs._relocate_gather(
            (tk,), tv, starts, tile_off, totals, r, m, s_round, t, cap, 2 * lp)
        return bkw[0], bv

    bk, bv = jax.block_until_ready(reloc_gather(tk, tv, starts, tile_off, totals))

    @jax.jit
    def compact_scatter(bk, bv, totals):
        okw, ov = bs._compact_scatter((bk,), bv, totals, r, s_round, cap, lp)
        return okw[0], ov

    @jax.jit
    def compact_gather(bk, bv, totals):
        okw, ov = bs._compact_gather((bk,), bv, totals, r, s_round, cap, lp)
        return okw[0], ov

    t_rel_sc = timeit(reloc_scatter, tk, tv, ranks, starts, tile_off,
                      repeats=repeats)
    t_rel_ga = timeit(reloc_gather, tk, tv, starts, tile_off, totals,
                      repeats=repeats)
    # NB: compaction here runs on the *uncompacted* bucket array (the real
    # pipeline compacts after the recursive sort) — identical shapes/cost.
    t_cmp_sc = timeit(compact_scatter, bk, bv, totals, repeats=repeats)
    t_cmp_ga = timeit(compact_gather, bk, bv, totals, repeats=repeats)
    rows.append(dict(
        name="step_breakdown/step8_relocation_scatter",
        us_per_call=t_rel_sc * 1e6, derived="legacy 1-D scatter (xla)"))
    rows.append(dict(
        name="step_breakdown/step8_relocation_gather",
        us_per_call=t_rel_ga * 1e6,
        derived=f"scatter-free; {t_rel_sc / max(t_rel_ga, 1e-12):.2f}x vs scatter"))
    rows.append(dict(
        name="step_breakdown/step9_compaction_scatter",
        us_per_call=t_cmp_sc * 1e6, derived="legacy 1-D scatter (xla)"))
    rows.append(dict(
        name="step_breakdown/step9_compaction_gather",
        us_per_call=t_cmp_ga * 1e6,
        derived=f"scatter-free; {t_cmp_sc / max(t_cmp_ga, 1e-12):.2f}x vs scatter"))

    # --- A/B: per-tile vs row-blocked Pallas local sort (interpret). ----
    t_pal_tile = t_pal_blk = None
    if pallas_compare:
        v = jnp.arange(lp, dtype=jnp.int32).reshape(m, t)
        uk = u.reshape(m, t) if lp == n else jnp.pad(u, (0, lp - n)).reshape(m, t)

        @functools.partial(jax.jit, static_argnames=("br",))
        def pal_sort(uk, v, br):
            return ops.sort_tiles(uk, v, impl="pallas", interpret=True,
                                  block_rows=br)

        t_pal_tile = timeit(lambda a, b: pal_sort(a, b, 1), uk, v,
                            repeats=repeats)
        t_pal_blk = timeit(lambda a, b: pal_sort(a, b, None), uk, v,
                           repeats=repeats)
        rows.append(dict(
            name="step_breakdown/step2_local_sort_pallas_per_tile",
            us_per_call=t_pal_tile * 1e6,
            derived=f"block_rows=1, grid={m} (interpret)"))
        rows.append(dict(
            name="step_breakdown/step2_local_sort_pallas_blocked",
            us_per_call=t_pal_blk * 1e6,
            derived=f"auto block_rows, "
                    f"{t_pal_tile / max(t_pal_blk, 1e-12):.2f}x vs per-tile"))

    # --- Acceptance row: local sort + relocation, baseline vs fused. ----
    base_ls = t_pal_tile if t_pal_tile is not None else t_local
    new_ls = t_pal_blk if t_pal_blk is not None else t_local
    base = base_ls + t_rel_sc + t_cmp_sc
    new = new_ls + t_rel_ga + t_cmp_ga
    rows.append(dict(
        name="step_breakdown/local_sort_plus_relocation_baseline",
        us_per_call=base * 1e6,
        derived="per-tile sort + scatter relocation/compaction"))
    rows.append(dict(
        name="step_breakdown/local_sort_plus_relocation_fused",
        us_per_call=new * 1e6,
        derived=f"blocked sort + gather relocation/compaction; "
                f"{base / max(new, 1e-12):.2f}x speedup (n={n})"))
    return rows
