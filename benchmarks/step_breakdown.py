"""Fig. 5 analogue: per-step timing of Algorithm 1 (C3).

The paper observes: local sort (step 2) + sublist sort (step 9)
dominate; deterministic-sampling overhead (steps 3-7) is small; the
relocation (step 8) is cheap because it is one coalesced pass.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import timeit
from repro.core import bucket_sort as bs
from repro.core.sort_config import SortConfig, round_up
from repro.kernels import ops

CFG = SortConfig(tile=4096, s=64, direct_max=8192, impl="xla")


def run(n=1048576, repeats=3):
    rng = np.random.default_rng(2)
    x = rng.integers(-(2**31), 2**31 - 1, n).astype(np.int32)
    u = ops.to_sortable(jnp.asarray(x))
    t, sper = CFG.tile, CFG.s
    lp = round_up(n, t)
    m = lp // t
    s_round = min(max(2 * lp // t and 64, 2), sper)

    @jax.jit
    def local_sort(u):
        v = jnp.arange(lp, dtype=jnp.int32)
        return ops.sort_tiles(u.reshape(m, t), v.reshape(m, t), impl="xla")

    tk, tv = jax.block_until_ready(local_sort(u))

    @jax.jit
    def sample_and_sort(tk, tv):
        idx = (jnp.arange(1, sper + 1, dtype=jnp.int32) * (t // sper)) - 1
        sk = tk[:, idx].reshape(1, m * sper)
        sv = tv[:, idx].reshape(1, m * sper)
        ssk, ssv, _ = bs._sort_rows(sk, sv, CFG, 2 * lp, None)
        return ssk, ssv

    ssk, ssv = jax.block_until_ready(sample_and_sort(tk, tv))

    @jax.jit
    def ranks_fn(tk, tv, ssk, ssv):
        sp_idx = (jnp.arange(1, s_round, dtype=jnp.int32) * (m * sper)) // s_round
        spk = jnp.repeat(ssk[:, sp_idx], m, axis=0)
        spv = jnp.repeat(ssv[:, sp_idx], m, axis=0)
        return ops.splitter_ranks(tk, tv, spk, spv, impl="xla")

    ranks = jax.block_until_ready(ranks_fn(tk, tv, ssk, ssv))

    @jax.jit
    def full(u):
        return bs._sort_canonical(u, CFG)

    rows = []
    t_local = timeit(local_sort, u, repeats=repeats)
    t_samp = timeit(sample_and_sort, tk, tv, repeats=repeats)
    t_rank = timeit(ranks_fn, tk, tv, ssk, ssv, repeats=repeats)
    t_full = timeit(full, u, repeats=repeats)
    rest = max(t_full - t_local - t_samp - t_rank, 0.0)
    for name, tt in [
        ("step2_local_sort", t_local),
        ("steps3-5_sampling", t_samp),
        ("step6_sample_indexing", t_rank),
        ("steps7-9_relocate_and_bucket_sort", rest),
        ("total", t_full),
    ]:
        frac = tt / t_full if t_full else 0
        rows.append(dict(name=f"step_breakdown/{name}", us_per_call=tt * 1e6,
                         derived=f"{100*frac:.1f}% of total (n={n})"))
    overhead = (t_samp + t_rank) / t_full
    rows.append(dict(
        name="step_breakdown/sampling_overhead_fraction", us_per_call=0.0,
        derived=f"{100*overhead:.1f}% (paper C3: small)"))
    return rows
