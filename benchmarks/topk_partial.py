"""Beyond-paper: partial deterministic sample sort for serving top-k
(vocab-scale logits) vs full sort and jax.lax.top_k."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import timeit
from repro.core import bucket_sort, partial_sort
from repro.core.sort_config import SortConfig

CFG = SortConfig(tile=4096, s=64, direct_max=8192, impl="xla")


def run(vocab=151936, k=64, repeats=3):
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.normal(size=vocab).astype(np.float32))
    t_part = timeit(lambda a: partial_sort.topk(a, k, CFG)[0], x, repeats=repeats)
    t_full = timeit(lambda a: bucket_sort.sort(a, CFG), x, repeats=repeats)
    t_lax = timeit(lambda a: jax.lax.top_k(a, k)[0], x, repeats=repeats)
    return [
        dict(name=f"topk_partial/partial_sample_sort_v={vocab}_k={k}",
             us_per_call=t_part * 1e6, derived=f"speedup_vs_full={t_full/t_part:.2f}x"),
        dict(name="topk_partial/full_sort", us_per_call=t_full * 1e6, derived=""),
        dict(name="topk_partial/lax_top_k", us_per_call=t_lax * 1e6,
             derived="XLA native reference"),
    ]
