"""C2 (the paper's core claim): deterministic sample sort's bucket sizes
and runtime are input-distribution independent; randomized sample
sort's fluctuate (and can overflow a static capacity on TPU).

Reports, per distribution: our max bucket fill (exact, deterministic)
vs randomized max fill across seeds, plus wall time.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import DISTRIBUTIONS, make_distribution, timeit
from repro.core import baselines, bucket_sort
from repro.core.sort_config import SortConfig

CFG = SortConfig(tile=4096, s=64, direct_max=8192, impl="xla")


def run(n=262144, repeats=2):
    rng = np.random.default_rng(3)
    rows = []
    det_fills, det_times = [], []
    rnd_fills = []
    for dist in DISTRIBUTIONS:
        x = jnp.asarray(make_distribution(dist, n, rng))
        srt, perm, stats = bucket_sort.sort_with_stats(x, CFG)
        fill = int(np.asarray(stats[0]["totals"]).max())
        cap = stats[0]["capacity"]
        tt = timeit(lambda a: bucket_sort.sort(a, CFG), x, repeats=repeats)
        det_fills.append(fill)
        det_times.append(tt)
        fills = []
        for seed in range(3):
            # max_attempts=1: raw single-shot mode so overflow stays
            # OBSERVABLE (the retry loop would mask the C2 quantity).
            _, _, (mf, ovf) = baselines.randomized_sample_sort(
                x, jax.random.PRNGKey(seed), CFG, capacity_factor=4.0,
                with_stats=True, max_attempts=1)
            fills.append(int(mf))
        rnd_fills.append(fills)
        rows.append(dict(
            name=f"distribution_robustness/{dist}", us_per_call=tt * 1e6,
            derived=f"det_fill={fill}/{cap} rand_fill={min(fills)}..{max(fills)}"))
    spread = (max(det_times) - min(det_times)) / np.mean(det_times)
    rows.append(dict(
        name="distribution_robustness/det_runtime_spread", us_per_call=0.0,
        derived=f"{100*spread:.1f}% across distributions (paper: ~0, <1ms)"))
    rows.append(dict(
        name="distribution_robustness/det_fill_spread", us_per_call=0.0,
        derived=f"max-min={max(det_fills)-min(det_fills)} "
                f"(bound holds: {max(det_fills)} <= cap)"))
    return rows
