"""Benchmark utilities: timing + distribution generators (paper §5)."""

from __future__ import annotations

import time

import jax
import numpy as np


def timeit(fn, *args, repeats: int = 3, warmup: int = 1):
    """Median wall time of fn(*args) with block_until_ready."""
    return timeit_stats(fn, *args, repeats=repeats, warmup=warmup)[0]


def timeit_stats(fn, *args, repeats: int = 3, warmup: int = 1):
    """Median-of-k timing with warmup-discard: run ``warmup`` calls
    (compile + cache effects, discarded), then ``repeats`` timed calls.

    Returns ``(median_s, spread)`` where spread is the relative
    half-range ``(max - min) / (2 * median)`` of the timed samples — a
    cheap noise indicator for rank-sensitive measurements (the autotune
    suite records it so flipped winners are attributable to timer
    noise rather than model error).
    """
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    med = float(np.median(ts))
    spread = float((max(ts) - min(ts)) / (2 * med)) if med > 0 else 0.0
    return med, spread


def spearman(a, b) -> float:
    """Spearman rank correlation of two equal-length sequences (no
    scipy dependency; average ranks are not needed for the distinct
    predicted costs the autotune suite feeds in)."""
    a = np.asarray(a, dtype=np.float64)
    b = np.asarray(b, dtype=np.float64)
    n = len(a)
    if n < 2:
        return 1.0

    def _ranks(v):
        r = np.empty(n)
        r[np.argsort(v, kind="stable")] = np.arange(n)
        return r

    ra, rb = _ranks(a), _ranks(b)
    return float(1.0 - 6.0 * np.sum((ra - rb) ** 2) / (n * (n * n - 1)))


# The six input distributions of Leischner et al. (the randomized sample
# sort paper) which the deterministic algorithm is immune to (C2).
def make_distribution(name: str, n: int, rng: np.random.Generator):
    if name == "uniform":
        return rng.integers(-(2**31), 2**31 - 1, n).astype(np.int32)
    if name == "gaussian":
        return (rng.normal(0, 2**29, n)).astype(np.int32)
    if name == "zipf":
        return (rng.zipf(1.3, n) % (2**31 - 1)).astype(np.int32)
    if name == "sorted":
        return np.sort(rng.integers(-(2**31), 2**31 - 1, n).astype(np.int32))
    if name == "reverse":
        return np.sort(rng.integers(-(2**31), 2**31 - 1, n).astype(np.int32))[::-1].copy()
    if name == "all-equal":
        return np.full(n, 123456789, np.int32)
    if name == "bucket-killer":
        # many duplicates of a few values — worst case for naive splitters
        return rng.choice(np.array([3, 7, 11], np.int32), n)
    if name == "nearly-sorted":
        # sorted data with ~1% random adjacent transpositions: long runs
        # survive, which is the merge strategy's home turf (DESIGN.md §8)
        x = np.sort(rng.integers(-(2**31), 2**31 - 1, n).astype(np.int32))
        idx = rng.integers(0, max(n - 1, 1), max(n // 100, 1))
        x[idx], x[idx + 1] = x[idx + 1].copy(), x[idx].copy()
        return x
    if name == "skewed":
        # heavy-tailed duplicates (zipf) — low top-bits entropy
        return (rng.zipf(1.3, n) % (2**31 - 1)).astype(np.int32)
    if name == "all-dup":
        return np.full(n, 42, np.int32)
    raise KeyError(name)


DISTRIBUTIONS = ["uniform", "gaussian", "zipf", "sorted", "reverse", "all-equal"]
