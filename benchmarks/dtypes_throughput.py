"""Key-codec dtype sweep: 64-bit vs 32-bit sorting throughput.

The §6 cost model predicts 64-bit keys cost up to ~1.5× the 32-bit
wall time (3 words/element moved instead of 2; the extra compare chain
is VPU noise) and ``descending`` costs nothing (codec-level
complement).  This suite records both ratios so the prediction is a
tracked number, not a claim.

Measurement discipline: CPU medians drift ~20% over a multi-minute
suite run (thermal/load), which swamps the effects being measured —
so every (dtype × order) cell is timed ROUND-ROBIN: one call per cell
per round, per-cell medians across rounds.  Drift then hits all cells
alike and the ratios stay honest.  CPU/xla wall-times are proxies for
the TPU target — the RATIO is the reproduced quantity.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bucket_sort
from repro.core.sort_config import SortConfig

CFG = SortConfig(tile=4096, s=64, direct_max=8192, impl="xla")
CFG_DESC = SortConfig(tile=4096, s=64, direct_max=8192, impl="xla",
                      descending=True)

DTYPES = ("int32", "float32", "bfloat16", "int64", "uint64", "float64")
DESC_DTYPES = ("int32", "int64")


def _keys(dtype: str, n: int, rng: np.random.Generator):
    if dtype == "int32":
        return rng.integers(-(2**31), 2**31 - 1, n).astype(np.int32)
    if dtype == "float32":
        return rng.normal(size=n).astype(np.float32)
    if dtype == "bfloat16":
        return rng.normal(size=n).astype(np.float32)  # cast at jnp boundary
    if dtype == "int64":
        return rng.integers(-(2**63), 2**63 - 1, n, dtype=np.int64)
    if dtype == "uint64":
        return rng.integers(0, 2**64, n, dtype=np.uint64)
    if dtype == "float64":
        return rng.normal(size=n).astype(np.float64)
    raise KeyError(dtype)


def run(n=1048576, repeats=3):
    rng = np.random.default_rng(0)
    rows = []
    with jax.experimental.enable_x64():
        cells = [(dt, False) for dt in DTYPES] + [
            (dt, True) for dt in DESC_DTYPES
        ]
        arrays, fns, samples = {}, {}, {}
        for dt, desc in cells:
            x = jnp.asarray(_keys(dt, n, rng))
            if dt == "bfloat16":
                x = x.astype(jnp.bfloat16)
            cfg = CFG_DESC if desc else CFG
            arrays[(dt, desc)] = x
            fns[(dt, desc)] = jax.jit(
                lambda a, c=cfg: bucket_sort.sort(a, c)
            )
            samples[(dt, desc)] = []
            jax.block_until_ready(fns[(dt, desc)](x))  # warmup/compile
        for _ in range(repeats):  # round-robin: drift hits cells alike
            for cell in cells:
                t0 = time.perf_counter()
                jax.block_until_ready(fns[cell](arrays[cell]))
                samples[cell].append(time.perf_counter() - t0)
        med = {c: float(np.median(s)) for c, s in samples.items()}
        for dt in DTYPES:
            t = med[(dt, False)]
            words = 2 if dt in ("int64", "uint64", "float64") else 1
            rows.append(dict(
                name=f"dtypes/sort_{dt}",
                us_per_call=t * 1e6,
                derived=f"rate={n / t / 1e6:.2f}Mkeys/s words={words} n={n}",
            ))
        for dt in DESC_DTYPES:
            t = med[(dt, True)]
            rows.append(dict(
                name=f"dtypes/sort_{dt}_descending",
                us_per_call=t * 1e6,
                derived=f"vs_ascending={t / med[(dt, False)]:.2f}x "
                        "(round-robin paired)",
            ))
    rows.append(dict(
        name="dtypes/ratio_64bit_vs_32bit",
        us_per_call=0.0,
        derived=(
            f"int64/int32={med[('int64', False)] / med[('int32', False)]:.2f}x "
            f"float64/float32="
            f"{med[('float64', False)] / med[('float32', False)]:.2f}x "
            "(§6 model: <=1.5x data movement)"
        ),
    ))
    return rows
