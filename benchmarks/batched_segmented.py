"""Batched & segmented sort suites (DESIGN.md §5): one batch launch vs
a python loop of per-row 1-D sorts, vs XLA's native row sort.

The paper's capacity bound holds per row, so B independent sorts ride
one `_sort_rows` recursion — the `batch_vs_loop` speedup is the whole
point of the subsystem (heavy-traffic serving: many vocab-sized rows
and ragged segments per request batch, not one giant array).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import timeit
from repro.core import baselines, bucket_sort, partial_sort
from repro.core.sort_config import SortConfig

CFG = SortConfig(tile=4096, s=64, direct_max=8192, impl="xla")


def run_batched(b=256, l=2048, k=64, repeats=3):
    rng = np.random.default_rng(7)
    x = jnp.asarray(rng.integers(-(2**31), 2**31 - 1, (b, l)).astype(np.int32))

    t_batch = timeit(lambda a: bucket_sort.sort_batched(a, CFG), x,
                     repeats=repeats)
    # Per-row loop: B separate 1-D pipeline launches (rows share one jit
    # cache entry — the loop cost is launches, not retracing).
    t_loop = timeit(
        lambda a: [bucket_sort.sort(a[i], CFG) for i in range(b)], x,
        repeats=repeats,
    )
    t_xla = timeit(lambda a: baselines.xla_sort_batched(a)[0], x,
                   repeats=repeats)

    logits = jnp.asarray(rng.normal(size=(b, l)).astype(np.float32))
    t_topk_b = timeit(lambda a: partial_sort.topk_batched(a, k, CFG)[0],
                      logits, repeats=repeats)
    t_topk_l = timeit(
        lambda a: [partial_sort.topk(a[i], k, CFG)[0] for i in range(b)],
        logits, repeats=repeats,
    )
    t_lax = timeit(lambda a: jax.lax.top_k(a, k)[0], logits, repeats=repeats)

    return [
        dict(name=f"batched/sort_batched_b={b}_l={l}",
             us_per_call=t_batch * 1e6,
             derived=f"batch_vs_loop={t_loop/t_batch:.2f}x "
                     f"xla_batched={t_xla*1e6:.0f}us"),
        dict(name=f"batched/sort_loop_b={b}_l={l}", us_per_call=t_loop * 1e6,
             derived="B separate 1-D launches"),
        dict(name=f"batched/topk_batched_b={b}_l={l}_k={k}",
             us_per_call=t_topk_b * 1e6,
             derived=f"batch_vs_loop={t_topk_l/t_topk_b:.2f}x "
                     f"lax_top_k={t_lax*1e6:.0f}us"),
    ]


def run_segmented(n=262144, segments=256, repeats=3):
    rng = np.random.default_rng(8)
    x = jnp.asarray(rng.integers(-(2**31), 2**31 - 1, n).astype(np.int32))
    # Mildly ragged serving-style segments (lengths ~ mean * U[0.5, 1.5],
    # a few empties): the packed width W = max length bounds the padding
    # waste, so wildly skewed raggedness belongs to the per-segment loop.
    w = rng.uniform(0.5, 1.5, segments)
    w[rng.integers(0, segments, max(segments // 32, 1))] = 0.0  # empties
    lens = np.floor(w / w.sum() * n).astype(np.int64)
    lens[-1] += n - lens.sum()
    off = np.concatenate([[0], np.cumsum(lens)])

    t_seg = timeit(lambda a: bucket_sort.segment_sort(a, off, CFG), x,
                   repeats=repeats)
    # Per-segment loop: one 1-D launch per non-empty segment; every
    # distinct length is its own jit signature (the retrace/launch cost
    # the packed layout removes).
    nz = [(int(off[i]), int(off[i + 1])) for i in range(segments)
          if lens[i] > 0]
    t_loop = timeit(
        lambda a: [bucket_sort.sort(a[lo:hi], CFG) for lo, hi in nz], x,
        repeats=repeats,
    )
    w = int(lens.max())
    return [
        dict(name=f"segmented/segment_sort_n={n}_s={segments}",
             us_per_call=t_seg * 1e6,
             derived=f"batch_vs_loop={t_loop/t_seg:.2f}x max_seg={w}"),
        dict(name=f"segmented/segment_loop_n={n}_s={segments}",
             us_per_call=t_loop * 1e6,
             derived=f"{len(nz)} per-segment launches"),
    ]
