"""--suite distributed: host-mesh strong scaling of the sharded sort.

Each D in {1, 2, 4, 8} runs in its OWN subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=D`` (the flag must
be set before jax import, and the parent bench process must keep its
real 1-device topology).  D=1 is the single-device ``sort_kv``
baseline at the same n_global; D>=2 builds a ``("data",)`` host mesh
and times the plan-aware ``make_sharded_sort`` runner end to end.

Host "devices" here share one CPU, so this measures the *overhead*
curve of the deal-round schedule (padding, s_loc sample, fixed-shape
all_to_all at c_pair, out_cap compaction) rather than real speedup —
the derived column records Mkeys/s and the efficiency vs the D=1
baseline so successive PRs can track schedule cost at fixed n_global.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys

_SELF = os.path.abspath(__file__)
_ROOT = os.path.dirname(os.path.dirname(_SELF))

DS = (1, 2, 4, 8)


def _child(d: int, n_global: int, repeats: int) -> None:
    # Runs under --xla_force_host_platform_device_count=d (set by run()).
    import jax.numpy as jnp
    import numpy as np

    from benchmarks.common import timeit
    from repro.core.sort_config import SortConfig

    cfg = SortConfig(tile=4096, s=64, direct_max=8192, impl="xla")
    rng = np.random.default_rng(7)
    x = jnp.asarray(rng.integers(-(2**31), 2**31 - 1, n_global).astype(np.int32))
    out: dict = dict(d=d, n_global=n_global)
    if d == 1:
        from repro.core import bucket_sort

        t = timeit(lambda a: bucket_sort.sort_kv(
            a, jnp.arange(n_global, dtype=jnp.int32), cfg), x,
            repeats=repeats)
        out["schedule"] = "single-device sort_kv baseline"
    else:
        from repro.core.distributed_sort import make_sharded_sort
        from repro.launch.mesh import make_mesh

        mesh = make_mesh((d,), ("data",))
        run_fn, plan = make_sharded_sort(mesh, "data", n_global, cfg)
        t = timeit(run_fn, x, repeats=repeats)
        out["schedule"] = (
            f"oversample={plan.oversample} c_pair={plan.c_pair} "
            f"out_cap={plan.out_cap} local={plan.run_plan.root.strategy}"
        )
    out["us_per_call"] = t * 1e6
    print("RESULT " + json.dumps(out), flush=True)


def run(n_global: int = 262144, repeats: int = 3, ds=DS):
    rows = []
    base_us = None
    for d in ds:
        env = dict(os.environ)
        env["XLA_FLAGS"] = (
            env.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count={d}"
        ).strip()
        env["PYTHONPATH"] = os.pathsep.join(
            p for p in (_ROOT, os.path.join(_ROOT, "src"),
                        env.get("PYTHONPATH", "")) if p)
        proc = subprocess.run(
            [sys.executable, _SELF, "--child", str(d), str(n_global),
             str(repeats)],
            env=env, capture_output=True, text=True, timeout=900)
        if proc.returncode != 0:
            raise RuntimeError(
                f"distributed bench child d={d} failed:\n{proc.stderr[-2000:]}")
        line = next(l for l in proc.stdout.splitlines()
                    if l.startswith("RESULT "))
        res = json.loads(line[len("RESULT "):])
        us = res["us_per_call"]
        if d == 1:
            base_us = us
        eff = (base_us / us) if base_us else float("nan")
        rows.append(dict(
            name=f"distributed/d{d}",
            us_per_call=us,
            derived=(
                f"n_global={n_global} rate={n_global / us:.2f}Mkeys/s "
                f"vs_d1={eff:.2f}x host-mesh {res['schedule']}"
            ),
        ))
    return rows


if __name__ == "__main__":
    if len(sys.argv) == 5 and sys.argv[1] == "--child":
        _child(int(sys.argv[2]), int(sys.argv[3]), int(sys.argv[4]))
    else:
        for row in run():
            print(f"{row['name']},{row['us_per_call']:.1f},{row['derived']}")
