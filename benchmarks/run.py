# One function per paper table/figure. Prints ``name,us_per_call,derived``
# CSV and writes the same rows to a machine-readable BENCH_sort.json so
# successive PRs accumulate a perf trajectory.
from __future__ import annotations

import argparse
import json
import os
import sys
import time

# Make `python benchmarks/run.py` work from anywhere: the repo root (and
# src/, for checkouts without `pip install -e .`) must be importable.
_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
for _p in (_ROOT, os.path.join(_ROOT, "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller sizes (CI/container friendly)")
    ap.add_argument("--only", default=None, help="comma-separated bench names")
    ap.add_argument("--suite", default=None,
                    help="comma-separated suite names: run only these and "
                         "MERGE their rows into the JSON record (rows from "
                         "suites not run are preserved — unlike --only, "
                         "which skips writing entirely)")
    ap.add_argument("--json", default=None,
                    help="output path for machine-readable rows; default "
                         "BENCH_sort.json, but a --only run does NOT "
                         "write unless --json is passed explicitly (the "
                         "file is the cross-PR perf record and a partial "
                         "row set would clobber it); '' disables")
    args = ap.parse_args()
    if args.suite and args.only:
        ap.error("--suite and --only are mutually exclusive")
    merge = bool(args.suite)
    if args.suite:
        args.only = args.suite
    if args.json is None:
        args.json = "" if (args.only and not merge) else "BENCH_sort.json"

    from benchmarks import (
        autotune_bench,
        batched_segmented,
        distributed_scaling,
        distribution_robustness,
        dtypes_throughput,
        guard_overhead,
        moe_dispatch,
        sample_size_sweep,
        sort_throughput,
        step_breakdown,
        strategies,
        topk_partial,
    )

    quick = args.quick
    suites = {
        "sort_throughput": lambda: sort_throughput.run(
            sizes=(65536, 262144) if quick else (65536, 262144, 1048576)),
        "sample_size_sweep": lambda: sample_size_sweep.run(
            n=131072 if quick else 524288,
            svals=(16, 64) if quick else (8, 16, 32, 64, 128)),
        "step_breakdown": lambda: step_breakdown.run(
            n=262144 if quick else 1048576),
        "distribution_robustness": lambda: distribution_robustness.run(
            n=65536 if quick else 262144),
        "moe_dispatch": lambda: moe_dispatch.run(
            tokens=4096 if quick else 16384),
        "topk_partial": lambda: topk_partial.run(
            vocab=65536 if quick else 151936),
        "dtypes": lambda: dtypes_throughput.run(
            n=131072 if quick else 1048576),
        "batched": lambda: batched_segmented.run_batched(
            b=64 if quick else 256, l=2048),
        "segmented": lambda: batched_segmented.run_segmented(
            n=65536 if quick else 262144, segments=64 if quick else 256),
        "autotune": lambda: autotune_bench.run(
            n=262144 if quick else 1048576,
            max_trials=8 if quick else 12,
            repeats=2 if quick else 3),
        "strategies": lambda: strategies.run(
            n=262144 if quick else 1048576),
        "distributed": lambda: distributed_scaling.run(
            n_global=65536 if quick else 262144,
            repeats=2 if quick else 3),
        "guard": lambda: guard_overhead.run(
            n=262144 if quick else 1048576,
            repeats=2 if quick else 3),
    }
    only = set(args.only.split(",")) if args.only else None
    if only:
        unknown = only - set(suites)
        if unknown:
            ap.error(
                f"unknown suite(s): {sorted(unknown)}; "
                f"valid suites: {', '.join(sorted(suites))}"
            )

    print("name,us_per_call,derived")
    failures = 0
    all_rows: list[dict] = []
    for name, fn in suites.items():
        if only and name not in only:
            continue
        t0 = time.time()
        try:
            for row in fn():
                all_rows.append(dict(
                    name=row["name"],
                    us_per_call=round(float(row["us_per_call"]), 1),
                    derived=str(row["derived"]),
                ))
                d = str(row["derived"]).replace(",", ";")
                print(f"{row['name']},{row['us_per_call']:.1f},{d}", flush=True)
        except Exception as e:  # pragma: no cover
            failures += 1
            all_rows.append(dict(name=name, us_per_call=None,
                                 derived=f"ERROR {type(e).__name__}: {e}"))
            print(f"{name},ERROR,{type(e).__name__}: {e}", flush=True)
        print(f"# {name} done in {time.time()-t0:.1f}s", file=sys.stderr)

    if args.json:
        ran = sorted(only) if only else sorted(suites)
        now = time.strftime("%Y-%m-%dT%H:%M:%S%z")
        suite_meta: dict = {}
        if merge and os.path.exists(args.json):
            # --suite: keep the recorded rows (and per-suite measurement
            # conditions) of suites NOT run this time.  Row names are
            # "<suite>/<case>"; suite-level ERROR rows are named bare
            # "<suite>".
            with open(args.json) as f:
                old = json.load(f)
            kept = [r for r in old.get("rows", [])
                    if r["name"].split("/")[0] not in only]
            all_rows = kept + all_rows
            suite_meta = {k: v for k, v in old.get("suite_meta", {}).items()
                          if k not in only}
        # quick/timestamp describe only THIS invocation; per-row
        # conditions live in suite_meta (rows can be merged across runs).
        for s in ran:
            suite_meta[s] = dict(quick=quick, timestamp=now)
        payload = dict(
            schema="bench_sort/v1",
            quick=quick,
            only=sorted(only) if only else None,
            timestamp=now,
            suite_meta=dict(sorted(suite_meta.items())),
            rows=all_rows,
        )
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2)
            f.write("\n")
        print(f"# wrote {len(all_rows)} rows to {args.json}", file=sys.stderr)

    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
