# One function per paper table/figure. Prints ``name,us_per_call,derived`` CSV.
from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller sizes (CI/container friendly)")
    ap.add_argument("--only", default=None, help="comma-separated bench names")
    args = ap.parse_args()

    from benchmarks import (
        distribution_robustness,
        moe_dispatch,
        sample_size_sweep,
        sort_throughput,
        step_breakdown,
        topk_partial,
    )

    quick = args.quick
    suites = {
        "sort_throughput": lambda: sort_throughput.run(
            sizes=(65536, 262144) if quick else (65536, 262144, 1048576)),
        "sample_size_sweep": lambda: sample_size_sweep.run(
            n=131072 if quick else 524288,
            svals=(16, 64) if quick else (8, 16, 32, 64, 128)),
        "step_breakdown": lambda: step_breakdown.run(
            n=262144 if quick else 1048576),
        "distribution_robustness": lambda: distribution_robustness.run(
            n=65536 if quick else 262144),
        "moe_dispatch": lambda: moe_dispatch.run(
            tokens=4096 if quick else 16384),
        "topk_partial": lambda: topk_partial.run(
            vocab=65536 if quick else 151936),
    }
    only = set(args.only.split(",")) if args.only else None

    print("name,us_per_call,derived")
    failures = 0
    for name, fn in suites.items():
        if only and name not in only:
            continue
        t0 = time.time()
        try:
            for row in fn():
                d = str(row["derived"]).replace(",", ";")
                print(f"{row['name']},{row['us_per_call']:.1f},{d}", flush=True)
        except Exception as e:  # pragma: no cover
            failures += 1
            print(f"{name},ERROR,{type(e).__name__}: {e}", flush=True)
        print(f"# {name} done in {time.time()-t0:.1f}s", file=sys.stderr)
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
