"""--suite autotune: the measured plan search at the acceptance size.

Runs ``core/autotune`` over the plan space (tile x s x block_rows x
fusion x relocation) for the ``sort_throughput`` signature
(int32, n = 2^20; quick: 2^18), records the default-config time, the
best-found plan (geometry in ``derived``) and its speedup into
BENCH_sort.json, then verifies a same-signature ``sort_planned`` call
on the cached winner performs zero retraces (the serving property).
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import autotune as autotune_mod
from repro.core import bucket_sort
from repro.core.sort_config import SortConfig

# Match benchmarks/sort_throughput.py: the CPU container measures the
# xla path; on TPU the pallas default kicks in via impl=None.
CFG = SortConfig(tile=4096, s=64, direct_max=8192, impl="xla")


def run(n=1048576, max_trials=12, repeats=3):
    res = autotune_mod.autotune(
        n, "int32", CFG, max_trials=max_trials, repeats=repeats
    )
    p = res.best_plan
    geom = (
        f"tile={p.root.tile or p.root.lp} s={p.root.s} "
        f"levels={p.num_levels} reloc={p.root.relocation} "
        f"block_rows={p.root.block_rows}"
    )
    rows = [
        dict(
            name=f"autotune/n={n}/default",
            us_per_call=res.default_us,
            derived=f"rate={n / res.default_us:.2f}Mkeys/s base config",
        ),
        dict(
            name=f"autotune/n={n}/best",
            us_per_call=res.best_us,
            derived=(
                f"rate={n / res.best_us:.2f}Mkeys/s "
                f"speedup={res.speedup:.2f}x "
                f"plan[{res.best_label}] {geom}"
            ),
        ),
    ]
    for t in sorted(res.trials, key=lambda t: t.us_per_call)[:5]:
        rows.append(
            dict(
                name=f"autotune/n={n}/trial[{t.label}]",
                us_per_call=t.us_per_call,
                derived=f"{res.trials[0].us_per_call / t.us_per_call:.2f}x vs base",
            )
        )

    # Zero-retrace check on the winner: the serving property the plan
    # cache exists for (same plan object -> same jit executable).
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.integers(-(2**31), 2**31 - 1, n).astype(np.int32))
    bucket_sort.sort_planned(x, p)
    t0 = bucket_sort.trace_count()
    bucket_sort.sort_planned(x, p)
    rows.append(
        dict(
            name=f"autotune/n={n}/retrace_on_reuse",
            us_per_call=0.0,
            derived=f"{bucket_sort.trace_count() - t0} (0 == plan reuse compiles nothing)",
        )
    )
    return rows
