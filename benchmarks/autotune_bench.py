"""--suite autotune: budgeted (cost-model pruned) plan search vs the
exhaustive measured search, at the acceptance sizes.

Four legs, all merged into BENCH_sort.json:

  * acceptance (local): exhaustive search over the full candidate space
    vs ``measure_budget=5`` at n — the budgeted winner must land within
    ``GAP_TOLERANCE`` (5%) of the exhaustive best wall time.  Rows end
    in "ok" / "FAIL" so CI can assert on the recorded text.
  * model error: predicted (cost model, HBM byte-equivalents) vs
    measured micros for every exhaustively-measured candidate, plus
    the Spearman rank correlation between the two orderings.
  * transfer: a fresh plan store is seeded at n, then ``plan_for`` at a
    NEW length must converge with <= 2 measurements (base +
    transferred winner) and still land within tolerance of the
    exhaustive best at that new length.
  * shard acceptance: the same exhaustive-vs-budgeted comparison for
    ``autotune_shard`` on a forced-host D=4 mesh, in a subprocess
    (``--xla_force_host_platform_device_count`` must be set before jax
    import; the parent keeps its real topology).
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
import tempfile

_SELF = os.path.abspath(__file__)
_ROOT = os.path.dirname(os.path.dirname(_SELF))

# Match benchmarks/sort_throughput.py: the CPU container measures the
# xla path; on TPU the pallas default kicks in via impl=None.
GAP_TOLERANCE = 0.05
BUDGET = 5


def _cfg():
    from repro.core.sort_config import SortConfig

    return SortConfig(tile=4096, s=64, direct_max=8192, impl="xla")


def _gap_row(name, best_us, ref_us, detail=""):
    gap = best_us / ref_us - 1.0
    ok = "ok" if gap <= GAP_TOLERANCE else "FAIL"
    return dict(
        name=name,
        us_per_call=best_us,
        derived=(
            f"gap={gap * 100:+.1f}% vs exhaustive "
            f"(tol {GAP_TOLERANCE * 100:.0f}%) {detail}{ok}"
        ),
    )


def _model_rows(prefix, result):
    """Predicted-vs-measured rows from one exhaustive AutotuneResult."""
    from benchmarks.common import spearman

    measured = [c for c in result.candidates if c.us_per_call is not None]
    rows = []
    if len(measured) >= 2:
        rho = spearman(
            [c.predicted for c in measured],
            [c.us_per_call for c in measured],
        )
        rows.append(dict(
            name=f"{prefix}/model_rank_corr",
            us_per_call=0.0,
            derived=(
                f"spearman={rho:.3f} over {len(measured)} measured "
                f"candidates (predicted cost vs wall time)"
            ),
        ))
    for c in sorted(measured, key=lambda c: c.us_per_call)[:5]:
        rows.append(dict(
            name=f"{prefix}/model[{c.label}]",
            us_per_call=c.us_per_call,
            derived=f"predicted={c.predicted:.0f} byte-equiv",
        ))
    return rows


def _count_measurements(autotune_mod, fn):
    """Run ``fn()`` counting autotune._measure invocations."""
    calls = []
    orig = autotune_mod._measure

    def _counting(f, x, **kw):
        calls.append(1)
        return orig(f, x, **kw)

    autotune_mod._measure = _counting
    try:
        out = fn()
    finally:
        autotune_mod._measure = orig
    return out, len(calls)


def run(n=1048576, max_trials=12, repeats=3, shard_d=4, shard_repeats=2):
    from repro.core import autotune as autotune_mod
    from repro.core import bucket_sort

    import jax.numpy as jnp
    import numpy as np

    cfg = _cfg()
    rows = []

    # --- acceptance (local): exhaustive vs budgeted ------------------
    exh = autotune_mod.autotune(
        n, "int32", cfg, max_trials=max_trials, repeats=repeats,
        measure_budget=None,
    )
    bud = autotune_mod.autotune(
        n, "int32", cfg, max_trials=max_trials, repeats=repeats,
        measure_budget=BUDGET,
    )
    n_meas = sum(1 for c in bud.candidates if c.us_per_call is not None)
    rows.append(dict(
        name=f"autotune/n={n}/exhaustive_best",
        us_per_call=exh.best_us,
        derived=(
            f"rate={n / exh.best_us:.2f}Mkeys/s plan[{exh.best_label}] "
            f"{len(exh.trials)} measured speedup={exh.speedup:.2f}x"
        ),
    ))
    # Re-measure both winners back-to-back with identical median-of-k
    # timing: each search's best_us is a min over noisy samples, and
    # the exhaustive one is a min over MORE samples (selection bias),
    # so comparing the raw numbers would over-report the gap.
    from benchmarks.common import timeit_stats

    rng0 = np.random.default_rng(2)
    x0 = jnp.asarray(
        rng0.integers(-(2**31), 2**31 - 1, n).astype(np.int32)
    )
    t_bud, _ = timeit_stats(
        lambda a: bucket_sort.sort_planned(a, bud.best_plan), x0,
        repeats=repeats + 1,
    )
    t_exh, _ = timeit_stats(
        lambda a: bucket_sort.sort_planned(a, exh.best_plan), x0,
        repeats=repeats + 1,
    )
    rows.append(_gap_row(
        f"autotune/n={n}/acceptance/budgeted",
        t_bud * 1e6, t_exh * 1e6,
        detail=f"{n_meas} measured plan[{bud.best_label}] ",
    ))
    rows.extend(_model_rows(f"autotune/n={n}", exh))

    # --- transfer: seed at n, converge at a new length ---------------
    n2 = n * 2
    with tempfile.TemporaryDirectory() as td:
        path = os.path.join(td, "plans.json")
        memo_bak = dict(autotune_mod._MEMO)
        autotune_mod.clear_memo()
        autotune_mod.plan_for(
            n, "int32", cfg, path=path, max_trials=max_trials,
            repeats=repeats, measure_budget=BUDGET,
        )
        plan2, meas2 = _count_measurements(
            autotune_mod,
            lambda: autotune_mod.plan_for(
                n2, "int32", cfg, path=path, max_trials=max_trials,
                repeats=repeats, measure_budget=BUDGET,
            ),
        )
        autotune_mod.clear_memo()
        autotune_mod._MEMO.update(memo_bak)
    exh2 = autotune_mod.autotune(
        n2, "int32", cfg, max_trials=max_trials,
        repeats=max(repeats - 1, 1), measure_budget=None,
    )
    rng = np.random.default_rng(3)
    x2 = jnp.asarray(rng.integers(-(2**31), 2**31 - 1, n2).astype(np.int32))
    from benchmarks.common import timeit_stats

    # Back-to-back re-measurement of BOTH winners with identical
    # median-of-k timing: the exhaustive search's best_us is a min over
    # many noisy samples (selection bias), so comparing a fresh
    # measurement against it would over-report the gap.
    t2, spread = timeit_stats(
        lambda a: bucket_sort.sort_planned(a, plan2), x2,
        repeats=repeats + 1,
    )
    t_ref, _ = timeit_stats(
        lambda a: bucket_sort.sort_planned(a, exh2.best_plan), x2,
        repeats=repeats + 1,
    )
    row = _gap_row(
        f"autotune/n={n2}/acceptance/transfer",
        t2 * 1e6, t_ref * 1e6,
        detail=f"{meas2} measurements (<=2) spread={spread * 100:.0f}% ",
    )
    if meas2 > 2:
        row["derived"] += " MEAS-FAIL"
    rows.append(row)

    # --- zero-retrace on the budgeted winner (serving property) ------
    x = jnp.asarray(
        np.random.default_rng(1).integers(-(2**31), 2**31 - 1, n)
        .astype(np.int32)
    )
    bucket_sort.sort_planned(x, bud.best_plan)
    t0 = bucket_sort.trace_count()
    bucket_sort.sort_planned(x, bud.best_plan)
    rows.append(dict(
        name=f"autotune/n={n}/retrace_on_reuse",
        us_per_call=0.0,
        derived=(
            f"{bucket_sort.trace_count() - t0} "
            f"(0 == plan reuse compiles nothing)"
        ),
    ))

    # --- shard acceptance on a forced-host D mesh --------------------
    rows.extend(_shard_leg(
        d=shard_d, n_global=n // 4, repeats=shard_repeats
    ))
    return rows


def _shard_leg(d: int, n_global: int, repeats: int):
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        env.get("XLA_FLAGS", "")
        + f" --xla_force_host_platform_device_count={d}"
    ).strip()
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in (_ROOT, os.path.join(_ROOT, "src"),
                    env.get("PYTHONPATH", "")) if p)
    proc = subprocess.run(
        [sys.executable, _SELF, "--shard-child", str(d), str(n_global),
         str(repeats)],
        env=env, capture_output=True, text=True, timeout=900)
    if proc.returncode != 0:
        raise RuntimeError(
            f"autotune shard child d={d} failed:\n{proc.stderr[-2000:]}")
    line = next(l for l in proc.stdout.splitlines()
                if l.startswith("RESULT "))
    res = json.loads(line[len("RESULT "):])
    rows = [dict(
        name=f"autotune/shard_d{d}/exhaustive_best",
        us_per_call=res["exh_best_us"],
        derived=(
            f"n_global={n_global} plan[{res['exh_label']}] "
            f"{res['exh_measured']} measured"
        ),
    )]
    rows.append(_gap_row(
        f"autotune/shard_d{d}/acceptance/budgeted",
        res["bud_best_us"], res["exh_best_us"],
        detail=f"{res['bud_measured']} measured "
               f"plan[{res['bud_label']}] ",
    ))
    rows.append(dict(
        name=f"autotune/shard_d{d}/model_rank_corr",
        us_per_call=0.0,
        derived=(
            f"spearman={res['spearman']:.3f} over "
            f"{res['exh_measured']} measured candidates"
        ),
    ))
    return rows


def _shard_child(d: int, n_global: int, repeats: int) -> None:
    # Runs under --xla_force_host_platform_device_count=d.
    from benchmarks.common import spearman
    from repro.core import autotune as autotune_mod
    from repro.launch.mesh import make_mesh

    cfg = _cfg()
    mesh = make_mesh((d,), ("data",))
    exh = autotune_mod.autotune_shard(
        mesh, "data", n_global, "int32", cfg,
        max_trials=8, repeats=repeats, measure_budget=None,
    )
    bud = autotune_mod.autotune_shard(
        mesh, "data", n_global, "int32", cfg,
        max_trials=8, repeats=repeats, measure_budget=BUDGET,
    )
    measured = [c for c in exh.candidates if c.us_per_call is not None]
    rho = spearman(
        [c.predicted for c in measured],
        [c.us_per_call for c in measured],
    ) if len(measured) >= 2 else 1.0
    # Unbiased winner comparison (see run(): search best_us is a min
    # over noisy samples): re-time both winner plans back to back.
    import numpy as np
    import jax.numpy as jnp

    from benchmarks.common import timeit_stats
    from repro.core import distributed_sort

    rng = np.random.default_rng(5)
    x = jnp.asarray(
        rng.integers(-(2**31), 2**31 - 1, n_global).astype(np.int32)
    )
    t_bud, _ = timeit_stats(
        lambda a: distributed_sort._sharded_argsort(a, mesh, bud.best_plan),
        x, repeats=repeats + 1,
    )
    t_exh, _ = timeit_stats(
        lambda a: distributed_sort._sharded_argsort(a, mesh, exh.best_plan),
        x, repeats=repeats + 1,
    )
    print("RESULT " + json.dumps(dict(
        d=d, n_global=n_global,
        exh_best_us=t_exh * 1e6, exh_label=exh.best_label,
        exh_measured=len(measured),
        bud_best_us=t_bud * 1e6, bud_label=bud.best_label,
        bud_measured=sum(
            1 for c in bud.candidates if c.us_per_call is not None
        ),
        spearman=rho,
    )), flush=True)


if __name__ == "__main__":
    if len(sys.argv) == 5 and sys.argv[1] == "--shard-child":
        _shard_child(int(sys.argv[2]), int(sys.argv[3]), int(sys.argv[4]))
    else:
        for r in run(n=262144, max_trials=8, repeats=2):
            print(f"{r['name']},{r['us_per_call']:.1f},{r['derived']}")
