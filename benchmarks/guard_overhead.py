"""Checked-mode overhead (DESIGN.md §11): the price of running the
paper's capacity invariant (``check='bounds'``) and the full
permutation+sortedness post-conditions (``check='full'``) on every
sort, versus ``check='off'``.

Acceptance (ISSUE 10): 'bounds' overhead <= 15% vs 'off' at n=2^20 on
the CPU proxy — recorded as an ok/FAIL row so the trajectory catches a
regression that makes checked mode unaffordable.
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

import jax

from benchmarks.common import timeit
from repro.core import bucket_sort, faults
from repro.core.sort_config import SortConfig

CFG = SortConfig(tile=4096, s=64, direct_max=8192, impl="xla")

ACCEPT_OVERHEAD = 0.15  # 'bounds' may cost at most 15% over 'off'


def _interleaved_medians(fns: dict, rounds: int) -> dict:
    """Round-robin timing: one call of each mode per round, medians per
    mode.  Machine drift hits all modes equally instead of whichever
    mode happened to run during the slow minute."""
    import time

    for fn in fns.values():  # warmup: compile every executable first
        jax.block_until_ready(fn())
    samples: dict = {k: [] for k in fns}
    for _ in range(rounds):
        for k, fn in fns.items():
            t0 = time.perf_counter()
            jax.block_until_ready(fn())
            samples[k].append(time.perf_counter() - t0)
    return {k: float(np.median(v)) for k, v in samples.items()}


def run(n=1048576, repeats=3):
    rng = np.random.default_rng(11)
    x = jnp.asarray(rng.integers(-(2**31), 2**31 - 1, n).astype(np.int32))
    rows = []
    cfgs = {c: dataclasses.replace(CFG, check=c)
            for c in ("off", "bounds", "full")}
    times = _interleaved_medians(
        {c: (lambda cfg=cfg: bucket_sort.sort(x, cfg))
         for c, cfg in cfgs.items()},
        rounds=max(repeats, 3))
    base = times["off"]
    for check in ("off", "bounds", "full"):
        ovh = times[check] / base - 1.0
        rows.append(dict(
            name=f"guard/check={check}", us_per_call=times[check] * 1e6,
            derived=(f"n={n} overhead={100*ovh:+.1f}% vs off"
                     if check != "off" else f"n={n} baseline")))

    # unarmed fault-site cost: pure dict lookup + counter increment
    faults.reset()
    t0 = timeit(lambda: None, repeats=repeats, warmup=0)
    t1 = timeit(lambda: faults.check("kernel.launch"), repeats=repeats,
                warmup=0)
    rows.append(dict(
        name="guard/faults_check_unarmed", us_per_call=(t1 - t0) * 1e6,
        derived="per-call cost of an unarmed faults.check site"))

    bounds_ovh = times["bounds"] / base - 1.0
    ok = bounds_ovh <= ACCEPT_OVERHEAD
    rows.append(dict(
        name="guard/acceptance/bounds_overhead", us_per_call=0.0,
        derived=(f"bounds={100*bounds_ovh:+.1f}% vs off at n={n} "
                 f"(budget {100*ACCEPT_OVERHEAD:.0f}%) "
                 + ("ok" if ok else "FAIL"))))
    return rows
