"""End-to-end driver: train a ~100M-param MoE LM for a few hundred steps
with the paper's sample-sort token dispatch, fault-tolerant runtime,
checkpoint/restore, and synthetic data.

  PYTHONPATH=src python examples/train_moe_lm.py --steps 300
"""

import argparse
import dataclasses

import jax
import numpy as np

from repro import sharding as shd
from repro.config import (
    ArchConfig, LayerSlot, ModelConfig, MoEConfig, OptimizerConfig,
    ParallelConfig, ShapeConfig,
)
from repro.data import SyntheticDataset
from repro.launch.mesh import make_mesh
from repro.launch.steps import build_train_step, make_plan, param_shardings
from repro.models import api, meta
from repro.optim import adamw_init
from repro.runtime import StragglerMonitor, TrainDriver

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=300)
ap.add_argument("--batch", type=int, default=8)
ap.add_argument("--seq", type=int, default=256)
ap.add_argument("--ckpt-dir", default="/tmp/repro_moe_example")
args = ap.parse_args()

# ~100M-param MoE: 8 layers, d=512, 16 experts top-2, sample-sort dispatch
model = ModelConfig(
    name="moe-100m", n_layers=8, d_model=512, n_heads=8, n_kv_heads=4,
    d_ff=1536, vocab=32000, layer_pattern=(LayerSlot("attn", "moe"),),
    moe=MoEConfig(n_experts=16, top_k=2, d_ff_expert=512,
                  dispatch="sample_sort"),
    param_dtype="float32", dtype="float32", attn_chunk=256, remat="none",
)
arch = ArchConfig(model=model)
tpl = api.template(model)
print(f"params: {meta.count_params(tpl)/1e6:.1f}M")

n_dev = len(jax.devices())
mesh = make_mesh((n_dev, 1), ("data", "model"))
par = ParallelConfig(mesh_shape=(n_dev, 1), mesh_axes=("data", "model"))
plan = make_plan(arch, ShapeConfig("ex", args.seq, args.batch, "train"), mesh, par)
opt = OptimizerConfig(lr=1e-3, warmup_steps=20, total_steps=args.steps)

with shd.sharding_ctx(mesh, plan.rules):
    jitted = jax.jit(build_train_step(plan, opt), donate_argnums=(0, 1))

    def init_state():
        params = meta.init_params(tpl, jax.random.PRNGKey(0))
        params = jax.tree.map(jax.device_put, params, param_shardings(plan))
        return (params, adamw_init(params, opt))

    def step_fn(state, batch):
        import jax.numpy as jnp
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        p, o = state
        p, o, m = jitted(p, o, batch)
        return (p, o), m

    ds = SyntheticDataset(model.vocab, args.seq, args.batch, seed=0)
    driver = TrainDriver(
        step_fn, init_state, ds, ckpt_dir=args.ckpt_dir, ckpt_every=100,
        log_every=20, monitor=StragglerMonitor(),
    )
    state, history = driver.run(args.steps)

losses = [h["loss"] for h in history]
print(f"loss: {losses[0]:.3f} -> {losses[-1]:.3f}")
assert losses[-1] < losses[0] and np.isfinite(losses[-1])
print("OK: loss decreased; checkpoints in", args.ckpt_dir)
