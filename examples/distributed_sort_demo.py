"""Multi-device deterministic sample sort (shard_map + fixed-capacity
all_to_all).  Runs on 8 forced host devices:

  PYTHONPATH=src python examples/distributed_sort_demo.py
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax.numpy as jnp
import numpy as np

from repro.core import SortConfig, make_sharded_sort
from repro.launch.mesh import make_mesh

mesh = make_mesh((4, 2), ("data", "model"))
cfg = SortConfig(tile=1024, s=32, direct_max=2048, impl="xla")
n = 1 << 17

run, spec = make_sharded_sort(mesh, ("data", "model"), n, cfg, oversample=8)
print(f"devices={spec.d} n={n} per-pair capacity={spec.c_pair} "
      f"(deterministic bound; randomized splitters admit NO static bound)")

rng = np.random.default_rng(0)
for dist, x in {
    "uniform": rng.integers(-2**31, 2**31 - 1, n).astype(np.int32),
    "zipf-skew": (rng.zipf(1.5, n) % 100000).astype(np.int32),
    "all-equal": np.full(n, 42, np.int32),
}.items():
    sk, sv, counts, mw = map(np.asarray, run(jnp.asarray(x)))
    oc = spec.out_cap
    got = np.concatenate([sk[i * oc : i * oc + counts[i]] for i in range(spec.d)])
    assert (got == np.sort(x)).all()
    print(f"{dist:10s}: OK  shard loads={counts.tolist()} max_pair_fill={mw.max()}/{spec.c_pair}")
