"""Multi-device deterministic sample sort (shard_map + fixed-capacity
all_to_all), driven by the frozen ShardPlan IR (DESIGN.md §9).  Runs on
8 forced host devices:

  PYTHONPATH=src python examples/distributed_sort_demo.py
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax.numpy as jnp
import numpy as np

from repro.core import SortConfig, make_sharded_sort
from repro.core.distributed_sort import trace_count
from repro.launch.mesh import make_mesh

mesh = make_mesh((4, 2), ("data", "model"))
cfg = SortConfig(tile=1024, s=32, direct_max=2048, impl="xla")
n = 1 << 17

run, plan = make_sharded_sort(mesh, ("data", "model"), n, cfg, oversample=8)
print(plan.describe())
print(f"devices={plan.d} n={n} per-pair capacity={plan.c_pair} "
      f"(deterministic bound; randomized splitters admit NO static bound)")

rng = np.random.default_rng(0)
for dist, x in {
    "uniform": rng.integers(-2**31, 2**31 - 1, n).astype(np.int32),
    "zipf-skew": (rng.zipf(1.5, n) % 100000).astype(np.int32),
    "all-equal": np.full(n, 42, np.int32),
}.items():
    sk, sv, counts, mw = map(np.asarray, run(jnp.asarray(x)))
    oc = plan.out_cap
    got = np.concatenate([sk[i * oc : i * oc + counts[i]] for i in range(plan.d)])
    assert (got == np.sort(x)).all()
    print(f"{dist:10s}: OK  shard loads={counts.tolist()} max_pair_fill={mw.max()}/{plan.c_pair}")

# The plan is a jit static argument: a fresh make_sharded_sort with the
# same signature returns the SAME memoized plan -> zero retraces.
run2, plan2 = make_sharded_sort(mesh, ("data", "model"), n, cfg, oversample=8)
t0 = trace_count()
run2(jnp.asarray(rng.integers(0, 1000, n).astype(np.int32)))
print(f"equal-signature rebuild: plan2 is plan={plan2 is plan}, "
      f"retraces={trace_count() - t0}")
