"""Batched & segmented sort in one launch (DESIGN.md §5).

Serving-shaped workloads sort many SMALL independent arrays — a batch
of vocab-sized logit rows, ragged per-request candidate lists — where a
python loop of 1-D sorts wastes the machine on launch overhead.  The
paper's capacity bound holds per row, so the whole batch rides one
static-shape pipeline.

  PYTHONPATH=src python examples/batched_sort.py
"""

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import (
    DEFAULT_CONFIG,
    argsort_batched,
    segment_argsort,
    segment_sort,
    sort_batched,
    topk_batched,
)

rng = np.random.default_rng(0)

# --- Batched: (B, L) -> every row sorted independently, ONE launch. ---
xs = jnp.asarray(rng.integers(0, 1000, (8, 20000)).astype(np.int32))
ys = sort_batched(xs, DEFAULT_CONFIG)
perms = argsort_batched(xs, DEFAULT_CONFIG)
assert (np.asarray(ys) == np.sort(np.asarray(xs), axis=1)).all()
assert (np.asarray(perms)
        == np.argsort(np.asarray(xs), axis=1, kind="stable")).all()
print(f"sort_batched: {xs.shape} rows each sorted, stable; "
      f"row 0 head = {np.asarray(ys)[0, :5]}")

# --- Segmented: ragged independent sorts given host-known offsets. ---
x = jnp.asarray(rng.normal(size=50_000).astype(np.float32))
offsets = [0, 3, 3, 20_000, 50_000]  # empty + tiny + large segments
y = segment_sort(x, offsets, DEFAULT_CONFIG)
perm = segment_argsort(x, offsets, DEFAULT_CONFIG)
for lo, hi in zip(offsets, offsets[1:]):
    assert (np.asarray(y)[lo:hi] == np.sort(np.asarray(x)[lo:hi])).all()
    assert set(np.asarray(perm)[lo:hi]) == set(range(lo, hi))  # no leaks
print(f"segment_sort: {len(offsets) - 1} ragged segments of n={x.shape[0]}, "
      "no element crossed a boundary")

# --- Batched top-k: the serving hot path, (batch, vocab) logits. ---
logits = jnp.asarray(rng.normal(size=(8, 50_257)).astype(np.float32))
tv, ti = topk_batched(logits, 40, DEFAULT_CONFIG)
lv, li = jax.lax.top_k(logits, 40)
assert (np.asarray(tv) == np.asarray(lv)).all()
assert (np.asarray(ti) == np.asarray(li)).all()
print(f"topk_batched: top-40 of {logits.shape} logits == jax.lax.top_k")
