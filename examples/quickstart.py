"""Quickstart: the paper's algorithm as a library.

  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    DEFAULT_CONFIG, PAPER_CONFIG, SortConfig, argsort, sort, sort_kv,
    sort_with_stats, topk,
)

rng = np.random.default_rng(0)

# 1. sort a million keys with GPU BUCKET SORT (TPU-adapted, static shapes)
x = jnp.asarray(rng.integers(-2**31, 2**31 - 1, 1_000_000).astype(np.int32))
y = sort(x)
assert bool((y[1:] >= y[:-1]).all())
print(f"sorted {x.shape[0]} keys; first={int(y[0])} last={int(y[-1])}")

# 2. stable argsort + key/value sort
keys = jnp.asarray(rng.integers(0, 10, 16).astype(np.int32))
vals = jnp.arange(16)
sk, sv = sort_kv(keys, vals)
print("stable kv sort:", np.asarray(sk)[:8], np.asarray(sv)[:8])

# 3. the paper's guarantee: bucket fill <= static capacity, ANY input
worst = jnp.asarray(np.full(200_000, 7, np.int32))  # all-equal adversary
_, _, stats = sort_with_stats(worst, DEFAULT_CONFIG)
for s in stats:
    print(f"round: capacity={s['capacity']} max_fill={int(np.asarray(s['totals']).max())} (guaranteed <=)")

# 4. partial sample sort: top-k over a vocab-sized array
logits = jnp.asarray(rng.normal(size=151_936).astype(np.float32))
v, i = topk(logits, 8)
lv, li = jax.lax.top_k(logits, 8)
assert (np.asarray(i) == np.asarray(li)).all()
print("top-8 ids:", np.asarray(i))

# 5. the paper's own geometry (2K tiles / s=64, Fig. 3)
y2 = sort(x[:100_000], PAPER_CONFIG)
assert bool((y2[1:] >= y2[:-1]).all())
print("paper-config sort OK")

# 6. the plan layer: the whole schedule is static data (DESIGN.md §7).
# Build a plan once and reuse it — every call with an equal plan hits
# the same compiled executable (zero retraces).
from repro.core import build_plan, sort_planned
from repro.core import bucket_sort

plan = build_plan(x.shape[0], x.dtype, DEFAULT_CONFIG)
print(plan.describe())
y3 = sort_planned(x, plan)
t0 = bucket_sort.trace_count()
y3 = sort_planned(x, plan)          # plan reuse: compiles nothing
assert bucket_sort.trace_count() == t0
print("plan reuse: zero retraces")

# 7. autotune-then-sort: measure the plan space once, persist the
# winner, serve every later same-signature call from the plan cache
# (~/.cache/repro_sort/plans.json or $REPRO_SORT_PLAN_CACHE).
# plan_for is exactly what SortConfig(plan="autotune") calls on a
# cache miss — invoked directly here so the demo can shrink the trial
# budget; the search runs ONCE, everything after is a cache hit.
from repro.core.autotune import plan_for

n_tune = 200_000
best = plan_for(n_tune, x.dtype, DEFAULT_CONFIG, max_trials=6, repeats=2)
print("autotuned winner:", best.describe().splitlines()[0])
y4 = sort_planned(x[:n_tune], best)
assert bool((y4[1:] >= y4[:-1]).all())

cfg_tuned = SortConfig(plan="autotune")      # the public-API spelling
t0 = bucket_sort.trace_count()
y5 = sort(x[:n_tune], cfg_tuned)             # cache hit: same plan object,
assert bucket_sort.trace_count() == t0       # zero retraces, no re-tuning
assert bool((y5 == y4).all())
print("autotuned sort OK (plan cached for future processes)")
