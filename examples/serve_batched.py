"""Batched serving example: prefill + decode with partial-sample-sort
top-k sampling (see repro/launch/serve.py for the full launcher).

  PYTHONPATH=src python examples/serve_batched.py
"""

import subprocess
import sys

subprocess.run(
    [sys.executable, "-m", "repro.launch.serve", "--arch", "qwen2-1.5b",
     "--smoke", "--requests", "4", "--prompt-len", "32", "--gen", "8"],
    check=True,
)
