"""Hypothesis property tests on the system's core invariants:

  * sort output is sorted AND a permutation of the input (any dtype/dist)
  * stability (equal keys keep input order)
  * the paper's guaranteed bucket bound: every round's max bucket fill
    <= capacity and the relocation scatter never drops an element
  * partial top-k == lax.top_k for arbitrary inputs
  * batched sort of B rows == B independent 1-D sorts (DESIGN.md §5)
  * segmented sort never leaks an element across a segment boundary,
    and stability holds per segment
  * key-codec encode/decode is a sorted-order-preserving bijection for
    every dtype (64-bit two-word encodings included, DESIGN.md §6)
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="optional dev dep (pip install -e '.[test]')"
)
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import bucket_sort, partial_sort
from repro.core.key_codec import codec_for
from repro.core.sort_config import SortConfig

CFG = SortConfig(tile=128, s=8, direct_max=256, impl="xla")

ints = st.lists(
    st.integers(min_value=-(2**31), max_value=2**31 - 1), min_size=1, max_size=3000
)
small_ints = st.lists(st.integers(min_value=0, max_value=5), min_size=1, max_size=3000)
floats = st.lists(
    st.floats(width=32, allow_nan=True, allow_infinity=True),
    min_size=1, max_size=2000,
)


@settings(max_examples=25, deadline=None)
@given(ints)
def test_sort_is_sorted_permutation(xs):
    x = np.asarray(xs, np.int32)
    out = np.asarray(bucket_sort.sort(jnp.asarray(x), CFG))
    np.testing.assert_array_equal(out, np.sort(x))


@settings(max_examples=25, deadline=None)
@given(small_ints)
def test_sort_stable_under_duplicates(xs):
    x = np.asarray(xs, np.int32)
    perm = np.asarray(bucket_sort.argsort(jnp.asarray(x), CFG))
    np.testing.assert_array_equal(perm, np.argsort(x, kind="stable"))


@settings(max_examples=15, deadline=None)
@given(floats)
def test_sort_floats_total_order(xs):
    x = np.asarray(xs, np.float32)
    out = np.asarray(bucket_sort.sort(jnp.asarray(x), CFG))
    ref = np.sort(x)  # numpy: NaNs last; ours: -NaN first, +NaN last
    a = np.sort(out[~np.isnan(out)])
    b = ref[~np.isnan(ref)]
    np.testing.assert_array_equal(a, b)
    assert np.isnan(out).sum() == np.isnan(ref).sum()


@settings(max_examples=15, deadline=None)
@given(small_ints)
def test_bucket_bound_guarantee(xs):
    """The paper's core claim: deterministic sampling => bucket fill is
    bounded by the static capacity, for ANY input (worst cases included)."""
    x = np.asarray(xs, np.int32)
    if len(x) <= CFG.direct_max:
        x = np.tile(x, (CFG.direct_max // max(len(x), 1)) + 2)[: CFG.direct_max * 3]
    srt, perm, stats = bucket_sort.sort_with_stats(jnp.asarray(x), CFG)
    assert len(stats) >= 1
    for stt in stats:
        max_fill = int(np.asarray(stt["totals"]).max())
        assert max_fill <= stt["capacity"], (max_fill, stt["capacity"])
        assert int(np.asarray(stt["max_within"])) < stt["capacity"]
    np.testing.assert_array_equal(np.asarray(srt), np.sort(x))


@settings(max_examples=20, deadline=None)
@given(
    st.lists(st.floats(width=32, allow_nan=False, allow_infinity=False),
             min_size=1, max_size=1500),
    st.integers(min_value=1, max_value=64),
)
def test_partial_topk_matches_lax(xs, k):
    x = np.asarray(xs, np.float32)
    k = min(k, len(x))
    tv, ti = partial_sort.topk(jnp.asarray(x), k, CFG)
    lv, li = jax.lax.top_k(jnp.asarray(x), k)
    np.testing.assert_array_equal(np.asarray(ti), np.asarray(li))
    np.testing.assert_array_equal(np.asarray(tv), np.asarray(lv))


# ----------------------------------------------------------------------
# Key codec (DESIGN.md §6)
# ----------------------------------------------------------------------


int64s = st.lists(
    st.integers(min_value=-(2**63), max_value=2**63 - 1),
    min_size=1, max_size=500,
)
floats64 = st.lists(
    st.floats(allow_nan=True, allow_infinity=True),
    min_size=1, max_size=500,
)


def _codec_bijection_case(x, descending):
    """encode/decode roundtrips exactly AND the lexicographic unsigned
    word order (index tiebreak) == jnp's stable (arg)sort order."""
    codec = codec_for(x.dtype, descending)
    words = codec.encode(x)
    back = codec.decode(words)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(x))
    wnp = [np.asarray(w) for w in words]
    n = x.shape[0]
    perm = np.lexsort(tuple([np.arange(n)] + list(reversed(wnp))))
    want = np.asarray(jnp.argsort(x, stable=True, descending=descending))
    np.testing.assert_array_equal(perm, want)


@settings(max_examples=25, deadline=None)
@given(int64s, st.booleans())
def test_codec_int64_bijection_preserves_order(xs, descending):
    with jax.experimental.enable_x64():
        _codec_bijection_case(jnp.asarray(np.asarray(xs, np.int64)),
                              descending)


@settings(max_examples=25, deadline=None)
@given(floats64, st.booleans())
def test_codec_float64_bijection_preserves_order(xs, descending):
    """Full float64 range incl. NaN/±inf; signed zeros normalized to
    +0.0 (our total order ranks -0.0 < +0.0 strictly, numpy ties them —
    the conformance suite pins the value-level agreement)."""
    x = np.asarray(xs, np.float64)
    x[x == 0.0] = 0.0
    with jax.experimental.enable_x64():
        _codec_bijection_case(jnp.asarray(x), descending)


# ----------------------------------------------------------------------
# Batched & segmented layer (DESIGN.md §5)
# ----------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(
    st.lists(st.integers(min_value=-(2**31), max_value=2**31 - 1),
             min_size=1, max_size=800),
    st.integers(min_value=1, max_value=5),
)
def test_batched_sort_equals_independent_sorts(xs, b):
    """sort_batched of B rows == B independent 1-D sorts, bit for bit
    (values AND stable permutations)."""
    row = np.asarray(xs, np.int32)
    x = np.stack([np.roll(row, 13 * i) for i in range(b)])  # distinct rows
    got = np.asarray(bucket_sort.sort_batched(jnp.asarray(x), CFG))
    gotp = np.asarray(bucket_sort.argsort_batched(jnp.asarray(x), CFG))
    for i in range(b):
        np.testing.assert_array_equal(
            got[i], np.asarray(bucket_sort.sort(jnp.asarray(x[i]), CFG))
        )
        np.testing.assert_array_equal(
            gotp[i], np.asarray(bucket_sort.argsort(jnp.asarray(x[i]), CFG))
        )


def _offsets_from_cuts(n, cuts):
    return np.asarray([0] + sorted(c % (n + 1) for c in cuts) + [n], np.int64)


@settings(max_examples=20, deadline=None)
@given(
    st.lists(st.integers(min_value=-(2**31), max_value=2**31 - 1),
             min_size=1, max_size=800),
    st.lists(st.integers(min_value=0, max_value=2**31 - 1), max_size=6),
)
def test_segment_sort_never_leaks_across_boundaries(xs, cuts):
    """Every segment of the output is a sorted PERMUTATION OF THE SAME
    SEGMENT of the input — no element crosses a boundary (empty and
    duplicate offsets included)."""
    x = np.asarray(xs, np.int32)
    off = _offsets_from_cuts(len(x), cuts)
    got = np.asarray(bucket_sort.segment_sort(jnp.asarray(x), off, CFG))
    for lo, hi in zip(off, off[1:]):
        np.testing.assert_array_equal(got[lo:hi], np.sort(x[lo:hi]))


@settings(max_examples=20, deadline=None)
@given(
    st.lists(st.integers(min_value=0, max_value=3), min_size=1, max_size=800),
    st.lists(st.integers(min_value=0, max_value=2**31 - 1), max_size=6),
)
def test_segment_argsort_stable_per_segment(xs, cuts):
    """Heavy duplicates: the per-segment permutation must equal numpy's
    stable argsort of that segment (global indices)."""
    x = np.asarray(xs, np.int32)
    off = _offsets_from_cuts(len(x), cuts)
    perm = np.asarray(bucket_sort.segment_argsort(jnp.asarray(x), off, CFG))
    for lo, hi in zip(off, off[1:]):
        np.testing.assert_array_equal(
            perm[lo:hi], lo + np.argsort(x[lo:hi], kind="stable")
        )
