"""Hypothesis property tests on the system's core invariants:

  * sort output is sorted AND a permutation of the input (any dtype/dist)
  * stability (equal keys keep input order)
  * the paper's guaranteed bucket bound: every round's max bucket fill
    <= capacity and the relocation scatter never drops an element
  * partial top-k == lax.top_k for arbitrary inputs
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="optional dev dep (pip install -e '.[test]')"
)
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import bucket_sort, partial_sort
from repro.core.sort_config import SortConfig

CFG = SortConfig(tile=128, s=8, direct_max=256, impl="xla")

ints = st.lists(
    st.integers(min_value=-(2**31), max_value=2**31 - 1), min_size=1, max_size=3000
)
small_ints = st.lists(st.integers(min_value=0, max_value=5), min_size=1, max_size=3000)
floats = st.lists(
    st.floats(width=32, allow_nan=True, allow_infinity=True),
    min_size=1, max_size=2000,
)


@settings(max_examples=25, deadline=None)
@given(ints)
def test_sort_is_sorted_permutation(xs):
    x = np.asarray(xs, np.int32)
    out = np.asarray(bucket_sort.sort(jnp.asarray(x), CFG))
    np.testing.assert_array_equal(out, np.sort(x))


@settings(max_examples=25, deadline=None)
@given(small_ints)
def test_sort_stable_under_duplicates(xs):
    x = np.asarray(xs, np.int32)
    perm = np.asarray(bucket_sort.argsort(jnp.asarray(x), CFG))
    np.testing.assert_array_equal(perm, np.argsort(x, kind="stable"))


@settings(max_examples=15, deadline=None)
@given(floats)
def test_sort_floats_total_order(xs):
    x = np.asarray(xs, np.float32)
    out = np.asarray(bucket_sort.sort(jnp.asarray(x), CFG))
    ref = np.sort(x)  # numpy: NaNs last; ours: -NaN first, +NaN last
    a = np.sort(out[~np.isnan(out)])
    b = ref[~np.isnan(ref)]
    np.testing.assert_array_equal(a, b)
    assert np.isnan(out).sum() == np.isnan(ref).sum()


@settings(max_examples=15, deadline=None)
@given(small_ints)
def test_bucket_bound_guarantee(xs):
    """The paper's core claim: deterministic sampling => bucket fill is
    bounded by the static capacity, for ANY input (worst cases included)."""
    x = np.asarray(xs, np.int32)
    if len(x) <= CFG.direct_max:
        x = np.tile(x, (CFG.direct_max // max(len(x), 1)) + 2)[: CFG.direct_max * 3]
    srt, perm, stats = bucket_sort.sort_with_stats(jnp.asarray(x), CFG)
    assert len(stats) >= 1
    for stt in stats:
        max_fill = int(np.asarray(stt["totals"]).max())
        assert max_fill <= stt["capacity"], (max_fill, stt["capacity"])
        assert int(np.asarray(stt["max_within"])) < stt["capacity"]
    np.testing.assert_array_equal(np.asarray(srt), np.sort(x))


@settings(max_examples=20, deadline=None)
@given(
    st.lists(st.floats(width=32, allow_nan=False, allow_infinity=False),
             min_size=1, max_size=1500),
    st.integers(min_value=1, max_value=64),
)
def test_partial_topk_matches_lax(xs, k):
    x = np.asarray(xs, np.float32)
    k = min(k, len(x))
    tv, ti = partial_sort.topk(jnp.asarray(x), k, CFG)
    lv, li = jax.lax.top_k(jnp.asarray(x), k)
    np.testing.assert_array_equal(np.asarray(ti), np.asarray(li))
    np.testing.assert_array_equal(np.asarray(tv), np.asarray(lv))
