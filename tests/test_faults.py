"""Chaos suite (DESIGN.md §11): every registered fault site is injected
— at the first hit and at a later hit — and the engine must either
produce the bitwise-correct result via its degradation chain or raise a
structured error naming the site.  Never a silent wrong answer, never a
hang."""
import dataclasses
import json
import os
import subprocess
import sys
import textwrap
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import autotune, baselines, bucket_sort, faults, guard
from repro.core.sort_config import SortConfig
from repro.data.pipeline import DataLoader, ProducerError, SyntheticDataset

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
CFG = SortConfig(tile=256, s=16, direct_max=512, impl="xla")


@pytest.fixture(autouse=True)
def _clean_state():
    faults.reset()
    guard.clear_degradation_log()
    yield
    faults.reset()
    guard.clear_degradation_log()


# ----------------------------------------------------------------------
# The injector itself
# ----------------------------------------------------------------------


def test_site_registry_is_closed():
    with pytest.raises(ValueError, match="unknown fault site"):
        faults.check("kernel.lunch")
    with pytest.raises(ValueError, match="unknown fault site"):
        with faults.inject("no.such.site"):
            pass
    for site in faults.SITES:
        faults.check(site)  # unarmed: counts, never raises
        assert faults.hits(site) == 1


def test_inject_fires_exactly_on_configured_hits():
    with faults.inject("cache.load", on_hit=2, count=2) as rule:
        faults.check("cache.load")  # hit 1: passes
        for expect_hit in (2, 3):
            with pytest.raises(faults.FaultInjected) as ei:
                faults.check("cache.load")
            assert ei.value.site == "cache.load"
            assert ei.value.hit == expect_hit
        faults.check("cache.load")  # hit 4: passes again
    assert rule.fired == 2
    faults.check("cache.load")  # rule disarmed outside the block


def test_inject_resets_hit_counter_on_entry():
    for _ in range(5):
        faults.check("cache.save")
    with faults.inject("cache.save", on_hit=1):
        with pytest.raises(faults.FaultInjected) as ei:
            faults.check("cache.save")
        assert ei.value.hit == 1  # relative to the block, not the process


def test_env_var_rules(monkeypatch):
    monkeypatch.setenv("REPRO_SORT_FAULTS", "cache.load:2, cache.save:1:3")
    faults.reset()  # invalidate the parsed-env cache
    faults.check("cache.load")
    with pytest.raises(faults.FaultInjected):
        faults.check("cache.load")
    for _ in range(3):
        with pytest.raises(faults.FaultInjected):
            faults.check("cache.save")
    faults.check("cache.save")  # past the count window
    monkeypatch.setenv("REPRO_SORT_FAULTS", "cache.load:zap")
    faults.reset()
    with pytest.raises(ValueError, match="REPRO_SORT_FAULTS"):
        faults.check("cache.load")


def test_seeded_probabilistic_mode_is_deterministic():
    def firing_pattern(seed):
        fired = []
        with faults.inject("autotune.measure", prob=0.5, seed=seed):
            for i in range(50):
                try:
                    faults.check("autotune.measure")
                    fired.append(False)
                except faults.FaultInjected:
                    fired.append(True)
        return fired

    a, b = firing_pattern(7), firing_pattern(7)
    assert a == b, "same seed must fire on the same hits"
    assert any(a) and not all(a)
    assert firing_pattern(8) != a


def test_validation_of_rule_parameters():
    with pytest.raises(ValueError):
        faults._Rule("cache.load", on_hit=0)
    with pytest.raises(ValueError):
        faults._Rule("cache.load", count=0)
    with pytest.raises(ValueError):
        faults._Rule("cache.load", prob=1.5)


# ----------------------------------------------------------------------
# Site: kernel.launch — degradation chain ends in a correct sort
# ----------------------------------------------------------------------


@pytest.mark.parametrize("on_hit,count", [(1, 10**6), (2, 10**6), (3, 1)])
def test_chaos_kernel_launch(rng, on_hit, count):
    # unique length per case => fresh plan => the trace actually runs
    # (compiled-cache hits skip trace-time fault sites)
    n = 2816 + 128 * on_hit + count % 7
    x = jnp.asarray(rng.integers(-(10**9), 10**9, n).astype(np.int32))
    cfg = dataclasses.replace(CFG, check="full")
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", guard.DegradationWarning)
        with faults.inject("kernel.launch", on_hit=on_hit, count=count):
            out = bucket_sort.sort(x, cfg)
    np.testing.assert_array_equal(np.asarray(out), np.sort(np.asarray(x)))


def test_chaos_kernel_launch_no_degrade_raises(rng):
    """sort_planned (degrade=False) surfaces the fault instead of
    silently substituting a different schedule."""
    x = jnp.asarray(rng.integers(0, 10**6, 2944).astype(np.int32))
    plan = bucket_sort.resolve_plan(x.shape[0], x.dtype, CFG)
    with faults.inject("kernel.launch", on_hit=1, count=10**6):
        with pytest.raises(Exception) as ei:
            bucket_sort.sort_planned(x, plan)
    assert "kernel.launch" in str(ei.value)


# ----------------------------------------------------------------------
# Sites: cache.load / cache.save — quarantine + memory-only fallback
# ----------------------------------------------------------------------


def _tuned_plan(path, n=2048, **kw):
    kw.setdefault("measure_budget", 1)
    return autotune.plan_for(
        n, jnp.int32, CFG, path=path, max_trials=2, repeats=1, **kw)


@pytest.mark.parametrize("on_hit", [1, 2])
def test_chaos_cache_load(tmp_path, rng, on_hit):
    path = str(tmp_path / "plans.json")
    autotune.clear_memo()
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", guard.DegradationWarning)
        with faults.inject("cache.load", on_hit=on_hit, count=10**6):
            plan = _tuned_plan(path)
    x = jnp.asarray(rng.integers(0, 10**6, 2048).astype(np.int32))
    np.testing.assert_array_equal(
        np.asarray(bucket_sort.sort_planned(x, plan)),
        np.sort(np.asarray(x)))


@pytest.mark.parametrize("on_hit", [1, 2])
def test_chaos_cache_save(tmp_path, rng, on_hit):
    path = str(tmp_path / "plans.json")
    autotune.clear_memo()
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", guard.DegradationWarning)
        with faults.inject("cache.save", on_hit=on_hit, count=10**6) as rule:
            plan = _tuned_plan(path)
    # the plan is served from memory even though persistence failed
    x = jnp.asarray(rng.integers(0, 10**6, 2048).astype(np.int32))
    np.testing.assert_array_equal(
        np.asarray(bucket_sort.sort_planned(x, plan)),
        np.sort(np.asarray(x)))
    if rule.fired:  # hit N past the store's write count never fires
        log = guard.degradation_log()
        assert any(ev.site == "cache.save" for ev in log)
    if os.path.exists(path):  # hit 2+: first write may have landed
        json.load(open(path))  # whatever exists must be intact JSON


# ----------------------------------------------------------------------
# Site: autotune.measure — bounded retry, then denylist + structured err
# ----------------------------------------------------------------------


def test_chaos_autotune_measure_transient(tmp_path, rng):
    """A fault on the first measurement only: with_retries absorbs it
    and tuning completes."""
    path = str(tmp_path / "plans.json")
    autotune.clear_memo()
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", guard.DegradationWarning)
        with faults.inject("autotune.measure", on_hit=1, count=1):
            plan = _tuned_plan(path)
    x = jnp.asarray(rng.integers(0, 10**6, 2048).astype(np.int32))
    np.testing.assert_array_equal(
        np.asarray(bucket_sort.sort_planned(x, plan)),
        np.sort(np.asarray(x)))
    assert any(ev.action == "retry" for ev in guard.degradation_log())


def test_chaos_autotune_measure_persistent(tmp_path):
    """Every measurement failing exhausts the retry budget for every
    candidate: structured error naming the site, and the failures are
    PERSISTED to the per-signature denylist."""
    path = str(tmp_path / "plans.json")
    autotune.clear_memo()
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", guard.DegradationWarning)
        with faults.inject("autotune.measure", on_hit=1, count=10**9):
            with pytest.raises(guard.SortRuntimeError) as ei:
                _tuned_plan(path)
    assert ei.value.site == "autotune.measure"


def test_denylist_skips_candidates_on_next_run(tmp_path, rng):
    """A candidate that failed terminally is recorded in the store's
    denylist and not measured again on the next tuning run."""
    path = str(tmp_path / "plans.json")
    autotune.clear_memo()
    # fail ONLY the first candidate's measurements (3 attempts), let the
    # rest succeed -> tuning completes, failure lands in the denylist
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", guard.DegradationWarning)
        with faults.inject("autotune.measure", on_hit=1,
                           count=autotune._MEASURE_ATTEMPTS):
            _tuned_plan(path, measure_budget=3)
    store = json.load(open(path))
    deny = store.get("denylist", {})
    assert deny, "terminal measurement failure must be denylisted"
    (key,) = deny.keys()
    assert len(deny[key]) == 1
    # next run (fresh memo, same store): denylisted label is skipped
    autotune.clear_memo()
    res_plan = _tuned_plan(path, measure_budget=3)
    x = jnp.asarray(rng.integers(0, 10**6, 2048).astype(np.int32))
    np.testing.assert_array_equal(
        np.asarray(bucket_sort.sort_planned(x, res_plan)),
        np.sort(np.asarray(x)))


# ----------------------------------------------------------------------
# Site: collective.exchange — retry, then gather-to-host degraded sort
# ----------------------------------------------------------------------


@pytest.mark.parametrize("on_hit", [1, 2])
def test_chaos_collective_exchange(on_hit):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    code = textwrap.dedent(f"""
        import warnings
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import faults, guard
        from repro.core.distributed_sort import make_sharded_sort
        from repro.core.sort_config import SortConfig
        from repro.launch.mesh import make_mesh
        mesh = make_mesh((4,), ("data",))
        cfg = SortConfig(tile=256, s=16, direct_max=512, impl="xla")
        n = 4096
        run, plan = make_sharded_sort(mesh, "data", n, cfg)
        rng = np.random.default_rng(0)
        x = rng.integers(-2**31, 2**31 - 1, n).astype(np.int32)
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", guard.DegradationWarning)
            with faults.inject("collective.exchange", on_hit={on_hit},
                               count=10**6):
                # the site fires at TRACE time and compiled plans never
                # re-trace, so each healthy hit must come from a fresh
                # plan signature before the asserted (faulted) call
                for i in range({on_hit} - 1):
                    warm, wplan = make_sharded_sort(
                        mesh, "data", 8192 * (i + 1), cfg)
                    warm(jnp.asarray(
                        rng.integers(0, 10**6, 8192 * (i + 1))
                        .astype(np.int32)))
                    assert warm.last_stats["degraded"] is False
                sk, sv, counts, mw = map(np.asarray, run(jnp.asarray(x)))
        oc = plan.out_cap
        got = np.concatenate(
            [sk[i*oc:i*oc+counts[i]] for i in range(plan.d)])
        assert (got == np.sort(x)).all(), "degraded sort must be correct"
        pv = np.concatenate(
            [sv[i*oc:i*oc+counts[i]] for i in range(plan.d)])
        assert (x[pv] == got).all(), "payloads must be a valid argsort"
        assert run.last_stats["degraded"] is True
        assert run.last_stats["retries"] == 1
        log = guard.degradation_log()
        assert any(ev.action == "retry" for ev in log)
        assert any(ev.action == "fallback" for ev in log)
        # a later call with the fault gone heals back to the mesh path
        faults.reset()
        sk2, sv2, counts2, mw2 = map(np.asarray, run(jnp.asarray(x)))
        assert run.last_stats["degraded"] is False
        got2 = np.concatenate(
            [sk2[i*oc:i*oc+counts2[i]] for i in range(plan.d)])
        assert (got2 == np.sort(x)).all()
        print("OK")
    """)
    r = subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True, text=True, timeout=420, env=env)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-4000:]}"


# ----------------------------------------------------------------------
# Site: pipeline.producer — propagate on next(), deterministic shutdown
# ----------------------------------------------------------------------


def test_chaos_pipeline_producer_first_hit():
    ds = SyntheticDataset(vocab=100, seq_len=8, batch=2, seed=0)
    with faults.inject("pipeline.producer", on_hit=1):
        dl = DataLoader(ds, start_step=0, prefetch=2)
        with pytest.raises(ProducerError) as ei:
            next(dl)
        dl.close()
    assert ei.value.site == "pipeline.producer"
    assert ei.value.step == 0
    assert isinstance(ei.value.__cause__, faults.FaultInjected)


def test_chaos_pipeline_producer_mid_stream_kill():
    """Satellite 2: kill the producer mid-stream — already-prefetched
    batches still arrive in order, then the next __next__ raises the
    structured error (never hangs), and close() joins the thread."""
    ds = SyntheticDataset(vocab=100, seq_len=8, batch=2, seed=0)
    with faults.inject("pipeline.producer", on_hit=4):
        dl = DataLoader(ds, start_step=5, prefetch=2)
        got = [next(dl) for _ in range(3)]
        for i, b in enumerate(got):
            np.testing.assert_array_equal(
                b["tokens"], ds.batch_at(5 + i)["tokens"])
        with pytest.raises(ProducerError) as ei:
            next(dl)
        dl.close()
    assert ei.value.step == 8  # 4th produced batch = step 5+3
    assert not dl._thread.is_alive(), "close() must join the producer"
    dl.close()  # idempotent


def test_pipeline_close_is_deterministic_and_idempotent():
    ds = SyntheticDataset(vocab=100, seq_len=8, batch=2, seed=0)
    dl = DataLoader(ds, start_step=0, prefetch=2)
    assert next(dl)["tokens"].shape == (2, 8)
    dl.close()
    assert not dl._thread.is_alive()
    dl.close()  # second close: no-op, no error
    with pytest.raises((StopIteration, ProducerError)):
        next(dl)  # a closed loader never blocks


# ----------------------------------------------------------------------
# Baseline retry loop (satellite 3): adversarial all-duplicates input
# ----------------------------------------------------------------------


def test_randomized_baseline_retries_on_adversarial_input(rng):
    """All-duplicates input defeats random splitter selection: every
    element lands in one bucket, overflowing any factor < s.  The retry
    loop must double its way out (or raise the structured error), while
    the deterministic sort handles the same input with zero retries."""
    n = 20_000
    x = jnp.asarray(np.full(n, 42, np.int32))
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", guard.DegradationWarning)
        try:
            srt, perm, (mf, ovf) = baselines.randomized_sample_sort(
                x, jax.random.PRNGKey(0), CFG, capacity_factor=1.0,
                with_stats=True, max_attempts=6)
        except guard.SortRuntimeError as e:
            assert e.site.startswith("baselines.randomized_sample_sort")
            return
    np.testing.assert_array_equal(np.asarray(srt), np.asarray(x))
    assert int(ovf) == 0
    retries = [ev for ev in guard.degradation_log() if ev.action == "retry"]
    assert retries, "factor 1.0 on all-duplicates must overflow at least once"
    # raw single-shot mode keeps the overflow observable and never raises
    _, _, (mf1, ovf1) = baselines.randomized_sample_sort(
        x, jax.random.PRNGKey(0), CFG, capacity_factor=1.0,
        with_stats=True, max_attempts=1)
    assert int(ovf1) > 0
    # the deterministic sort needs no retry on the same adversarial input
    guard.clear_degradation_log()
    out = bucket_sort.sort(x, CFG)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(x))
    assert guard.degradation_log() == ()


def test_randomized_baseline_exhaustion_raises():
    n = 20_000
    x = jnp.asarray(np.full(n, 7, np.int32))
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", guard.DegradationWarning)
        with pytest.raises(guard.SortRuntimeError) as ei:
            baselines.randomized_sample_sort(
                x, jax.random.PRNGKey(0), CFG, capacity_factor=0.125,
                max_attempts=2)
    assert "overflow persisted" in ei.value.detail
