"""Substrates: optimizer, schedules, data pipeline, checkpointing,
fault-tolerant runtime (crash -> restart determinism), sharding rules."""

import os

import jax
import jax.numpy as jnp
import numpy as np

from repro import sharding as shd
from repro.config import OptimizerConfig, ParallelConfig
from repro.checkpoint import AsyncCheckpointer, latest_step, restore, save
from repro.data import DataLoader, MemmapDataset, SyntheticDataset
from repro.optim import adamw_init, adamw_update, clip_by_global_norm, cosine_warmup
from repro.runtime import StragglerMonitor, TrainDriver
from repro.runtime.driver import fit_parallel_to_devices


# ------------------------------------------------------------ optimizer
def test_adamw_matches_reference(rng):
    opt = OptimizerConfig(lr=1e-2, beta1=0.9, beta2=0.99, eps=1e-8, weight_decay=0.1)
    p = {"w": jnp.asarray(rng.normal(size=(8,)).astype(np.float32))}
    g = {"w": jnp.asarray(rng.normal(size=(8,)).astype(np.float32))}
    st = adamw_init(p, opt)
    p2, st2 = adamw_update(p, g, st, opt, jnp.float32(1e-2))
    m = 0.1 * np.asarray(g["w"])
    v = 0.01 * np.asarray(g["w"]) ** 2
    mh, vh = m / (1 - 0.9), v / (1 - 0.99)
    ref = np.asarray(p["w"]) - 1e-2 * (mh / (np.sqrt(vh) + 1e-8) + 0.1 * np.asarray(p["w"]))
    np.testing.assert_allclose(np.asarray(p2["w"]), ref, rtol=1e-5)
    assert int(st2["step"]) == 1


def test_clip_and_schedule():
    g = {"a": jnp.full((4,), 10.0)}
    clipped, gnorm = clip_by_global_norm(g, 1.0)
    assert abs(float(gnorm) - 20.0) < 1e-4
    np.testing.assert_allclose(
        np.asarray(clipped["a"]), np.full(4, 0.5), rtol=1e-5
    )
    lrs = [float(cosine_warmup(jnp.int32(s), 1.0, 10, 100)) for s in [0, 5, 10, 100]]
    assert lrs[0] == 0.0 and abs(lrs[1] - 0.5) < 1e-5 and lrs[2] >= lrs[3]


def test_adamw_bf16_moments():
    opt = OptimizerConfig(moment_dtype="bfloat16")
    p = {"w": jnp.ones((4,), jnp.bfloat16)}
    st = adamw_init(p, opt)
    assert st["m"]["w"].dtype == jnp.bfloat16
    p2, st2 = adamw_update(p, {"w": jnp.ones((4,), jnp.bfloat16)}, st, opt, 1e-3)
    assert p2["w"].dtype == jnp.bfloat16
    assert np.isfinite(np.asarray(p2["w"], np.float32)).all()


# ----------------------------------------------------------------- data
def test_synthetic_deterministic_seekable():
    ds = SyntheticDataset(vocab=100, seq_len=16, batch=4, seed=7)
    b1, b2 = ds.batch_at(42), ds.batch_at(42)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert not np.array_equal(ds.batch_at(43)["tokens"], b1["tokens"])
    np.testing.assert_array_equal(b1["targets"][:, :-1], b1["tokens"][:, 1:])
    assert b1["tokens"].min() >= 0 and b1["tokens"].max() < 100


def test_memmap_dataset(tmp_path):
    toks = np.arange(17 * 40, dtype=np.int32) % 97
    path = str(tmp_path / "tokens.bin")
    toks.tofile(path)
    ds = MemmapDataset(path, seq_len=16, batch=2, shard_idx=1, n_shards=2)
    b = ds.batch_at(0)
    assert b["tokens"].shape == (2, 16)
    np.testing.assert_array_equal(b["tokens"], ds.batch_at(0)["tokens"])
    b0 = MemmapDataset(path, 16, 2, 0, 2).batch_at(0)
    assert not np.array_equal(b0["tokens"], b["tokens"])  # shards differ


def test_loader_prefetch_order():
    ds = SyntheticDataset(vocab=50, seq_len=8, batch=2, seed=0)
    dl = DataLoader(ds, start_step=5, prefetch=2)
    got = [next(dl) for _ in range(3)]
    dl.close()
    for i, b in enumerate(got):
        np.testing.assert_array_equal(b["tokens"], ds.batch_at(5 + i)["tokens"])


# ----------------------------------------------------------- checkpoint
def test_checkpoint_roundtrip_atomic_gc(tmp_path):
    d = str(tmp_path / "ck")
    tree = {"a": jnp.arange(6).reshape(2, 3), "n": {"b": jnp.float32(3.5)}}
    for step in (1, 2, 3, 4):
        save(d, step, tree)
    assert latest_step(d) == 4
    # partial write must be ignored
    os.makedirs(os.path.join(d, "step_00000099.tmp"), exist_ok=True)
    assert latest_step(d) == 4
    like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)
    got = restore(d, 4, like)
    np.testing.assert_array_equal(np.asarray(got["a"]), np.asarray(tree["a"]))
    assert float(got["n"]["b"]) == 3.5
    from repro.checkpoint.ckpt import gc_keep_k
    gc_keep_k(d, 2)
    assert latest_step(d) == 4
    assert not os.path.exists(os.path.join(d, "step_00000001"))


def test_async_checkpointer(tmp_path):
    d = str(tmp_path / "ck")
    ck = AsyncCheckpointer(d, keep=2)
    for s in (10, 20, 30):
        ck.save(s, {"x": jnp.full((4,), s)})
    ck.wait()
    assert latest_step(d) == 30
    got = restore(d, 30, {"x": jax.ShapeDtypeStruct((4,), jnp.int32)})
    assert int(np.asarray(got["x"])[0]) == 30


# -------------------------------------------------------------- runtime
def _toy_driver(tmp_path, ckpt_every=5):
    def init_state():
        return {"w": jnp.float32(0.0), "step": jnp.int32(0)}

    def step_fn(state, batch):
        w = state["w"] + float(batch["tokens"].mean())
        return {"w": w, "step": state["step"] + 1}, {"loss": w}

    ds = SyntheticDataset(vocab=10, seq_len=4, batch=2, seed=1)
    return TrainDriver(
        step_fn, init_state, ds, ckpt_dir=os.path.join(str(tmp_path), "ck"),
        ckpt_every=ckpt_every, log_every=100, log_fn=lambda *_: None,
    )


def test_driver_crash_restart_deterministic(tmp_path):
    class Boom(RuntimeError):
        pass

    drv = _toy_driver(tmp_path)

    def injector(step):
        if step == 12:
            raise Boom()

    try:
        drv.run(20, fault_injector=injector)
        raise AssertionError("should have crashed")
    except Boom:
        pass
    # restart: resumes from step 10 checkpoint and replays the same data
    drv2 = _toy_driver(tmp_path)
    state, _ = drv2.run(20)
    drv3 = _toy_driver(str(tmp_path) + "_clean")
    state_clean, _ = drv3.run(20)
    np.testing.assert_allclose(
        float(state["w"]), float(state_clean["w"]), rtol=1e-6
    )


def test_straggler_monitor(tmp_path):
    hb = str(tmp_path / "hb.json")
    mon = StragglerMonitor(window=20, z_thresh=3.0, heartbeat_path=hb)
    for i in range(15):
        assert not mon.record(i, 0.10 + 0.001 * (i % 3))
    assert mon.record(15, 1.0)  # 10x outlier
    assert mon.flagged and mon.flagged[0][0] == 15
    assert os.path.exists(hb)


def test_elastic_mesh_fit():
    p = ParallelConfig(mesh_shape=(2, 16, 16), mesh_axes=("pod", "data", "model"))
    p2 = fit_parallel_to_devices(p, 256)  # lost a pod
    assert dict(zip(p2.mesh_axes, p2.mesh_shape))["model"] == 16
    assert np.prod(p2.mesh_shape) == 256
    p3 = fit_parallel_to_devices(p, 1024)  # doubled
    assert np.prod(p3.mesh_shape) == 1024


# ------------------------------------------------------------- sharding
def test_resolve_rules_divisibility_and_fallback():
    rules = shd.default_rules(fsdp=True, batch_axes=("data",), fsdp_axes=("data",))
    sizes = {"data": 16, "model": 16}
    # kv_heads=2 not divisible -> head_dim fallback takes "model"
    spec = shd.resolve(("embed", "kv_heads", "head_dim"), rules, sizes,
                       shape=(1024, 2, 128))
    assert spec == jax.sharding.PartitionSpec("data", None, "model")
    # heads divisible -> heads gets model, head_dim left alone
    spec = shd.resolve(("embed", "heads", "head_dim"), rules, sizes,
                       shape=(1024, 48, 128))
    assert spec == jax.sharding.PartitionSpec("data", "model")
    # no double-use of one mesh axis; rule PRIORITY wins (heads > mlp)
    spec = shd.resolve(("mlp", "heads"), rules, sizes, shape=(256, 32))
    assert spec == jax.sharding.PartitionSpec(None, "model")
    # seq-TP: qk_seq takes model only when heads can't
    spec = shd.resolve(("batch", "qk_seq", "heads", "head_dim"), rules, sizes,
                       shape=(32, 4096, 24, 128))
    assert spec == jax.sharding.PartitionSpec("data", "model")
    spec = shd.resolve(("batch", "qk_seq", "heads", "head_dim"), rules, sizes,
                       shape=(32, 4096, 48, 128))
    assert spec == jax.sharding.PartitionSpec("data", None, "model")
