"""ShardPlan IR tests: capacity-bound math, deal-round sampling
property, build determinism, serialization, validation messages, and
the shard-plan file round-trip.

Everything here is HOST math (``shard_geometry`` / ``build_shard_plan``
/ the numpy deal simulation) — no device mesh is needed, so these run
in the main 1-CPU pytest process.  The executor-side counterparts
(conformance, trace discipline, cache-hit zero-retrace) live in
``tests/test_distributed.py`` behind the subprocess harness.
"""

import dataclasses
import json

import numpy as np
import pytest

from repro.core import autotune as autotune_mod
from repro.core.distributed_sort import DistSortSpec
from repro.core.plan import (
    build_shard_plan,
    shard_geometry,
    shard_plan_from_dict,
    shard_plan_to_dict,
)
from repro.core.sort_config import SortConfig

_XLA = SortConfig(tile=256, s=16, direct_max=512, impl="xla")


# ----------------------------------------------------------------------
# Capacity-bound invariants (DESIGN.md §9) over random geometry
# ----------------------------------------------------------------------


def _assert_geometry_invariants(n_local, d, oversample, pair_align):
    g = shard_geometry(n_local, d, oversample, pair_align)
    # sampling geometry: s_loc samples spaced exactly n_pad/s_loc apart
    assert g.s_loc == oversample * d
    assert g.n_pad >= n_local and g.n_pad % g.s_loc == 0
    assert g.n_pad - n_local < g.s_loc, "n_pad padding not minimal"
    assert g.n_pad % d == 0, "deal needs n_pad divisible by d"
    # the paper's bucket bound: B_t <= n_pad * (1 + 1/c), exactly
    assert g.b_t == g.n_pad + g.n_pad // oversample
    assert g.b_t <= g.n_pad * (1 + 1 / oversample)
    # deal bound: per-pair chunk <= ceil(B_t/D) + D, lane-aligned with
    # EXACT padding (less than one alignment unit of slack)
    raw = -(-g.b_t // d) + d
    assert g.c_pair >= raw and g.c_pair % pair_align == 0
    assert g.c_pair - raw < pair_align, "c_pair padding not exact"
    # out_cap covers any achievable bucket total (<= B_t) and never
    # exceeds what the exchange can deliver (d * c_pair)
    assert g.out_cap >= g.b_t
    assert g.out_cap <= d * g.c_pair


def _random_geometry(seed):
    r = np.random.default_rng(seed)
    return (
        int(r.integers(1, 100_000)),
        int(r.integers(2, 33)),  # d need not be a power of two
        int(2 ** r.integers(0, 7)),
        int(2 ** r.integers(3, 9)),
    )


try:  # optional dev dep (pip install -e '.[test]')
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=60, deadline=None)
    @given(
        st.integers(min_value=1, max_value=100_000),
        st.integers(min_value=2, max_value=32),
        st.integers(min_value=0, max_value=6),
        st.integers(min_value=3, max_value=8),
    )
    def test_shard_geometry_capacity_invariants(n_local, d, oexp, paexp):
        _assert_geometry_invariants(n_local, d, 2**oexp, 2**paexp)

except ModuleNotFoundError:  # seeded fallback keeps the invariant tested

    @pytest.mark.parametrize("seed", range(16))
    def test_shard_geometry_capacity_invariants(seed):
        _assert_geometry_invariants(*_random_geometry(seed))


def test_spec_delegates_to_shard_geometry():
    """DistSortSpec is the minimal arithmetic view — every derived
    capacity must agree with the single source of truth."""
    for seed in range(8):
        n_local, d, oversample, pair_align = _random_geometry(seed)
        spec = DistSortSpec("data", d, n_local, oversample, pair_align)
        g = shard_geometry(n_local, d, oversample, pair_align)
        assert (spec.s_loc, spec.n_pad, spec.b_t, spec.c_pair, spec.out_cap) \
            == (g.s_loc, g.n_pad, g.b_t, g.c_pair, g.out_cap)
        plan = build_shard_plan(
            "data", d, n_local, "int32", _XLA,
            oversample=oversample, pair_align=pair_align,
        )
        assert (plan.s_loc, plan.n_pad, plan.b_t, plan.c_pair, plan.out_cap) \
            == (g.s_loc, g.n_pad, g.b_t, g.c_pair, g.out_cap)


# ----------------------------------------------------------------------
# Deal round: numpy simulation of the executor's reshape/swapaxes
# transpose — every device must receive a stride-D regular sample of
# every source's sorted run (what the capacity proof relies on)
# ----------------------------------------------------------------------


@pytest.mark.parametrize("seed", range(8))
def test_deal_round_leaves_stride_d_regular_samples(seed):
    r = np.random.default_rng(seed)
    d = int(2 ** r.integers(1, 4))
    oversample = int(2 ** r.integers(0, 4))
    g = shard_geometry(int(r.integers(1, 5000)), d, oversample)
    runs = [np.sort(r.integers(0, 2**31, g.n_pad)) for _ in range(d)]
    for t in range(d):
        # _deal_all_to_all: x.reshape(n_pad//d, d).swapaxes(0,1) then
        # all_to_all(split=0) -> device t holds row t of every source
        received = [
            x.reshape(g.n_pad // d, d).swapaxes(0, 1)[t] for x in runs
        ]
        for j, chunk in enumerate(received):
            np.testing.assert_array_equal(chunk, runs[j][t::d])
            assert (chunk[1:] >= chunk[:-1]).all(), "sample not sorted"
            assert chunk.shape == (g.n_pad // d,)


# ----------------------------------------------------------------------
# Plan build: determinism, memoization, signatures
# ----------------------------------------------------------------------


def test_build_shard_plan_deterministic_and_memoized():
    a = build_shard_plan("data", 4, 2048, "int32", _XLA)
    b = build_shard_plan(("data",), 4, 2048, "int32", _XLA)
    assert a is b, "axis-normalized rebuild must hit the assembly memo"
    assert a == b and hash(a) == hash(b)
    # per-phase sub-plans carry the strategy dispatch of the config
    radix = build_shard_plan(
        "data", 4, 2048, "int32",
        dataclasses.replace(_XLA, strategy="radix"),
    )
    assert radix.run_plan.root.strategy == "radix"
    assert radix != a and radix.signature() != a.signature()


def test_shard_plan_signature_separates_schedules():
    base = build_shard_plan("data", 4, 2048, "int32", _XLA)
    for other in (
        build_shard_plan("data", 4, 2048, "int32", _XLA, oversample=4),
        build_shard_plan("data", 4, 2048, "int32", _XLA, pair_align=128),
        build_shard_plan("data", 4, 2048, "uint32", _XLA),
        build_shard_plan("data", 4, 1024, "int32", _XLA),
        build_shard_plan(("data", "model"), 4, 2048, "int32", _XLA),
        build_shard_plan(
            "data", 4, 2048, "int32",
            dataclasses.replace(_XLA, descending=True),
        ),
    ):
        assert other.signature() != base.signature()
        assert autotune_mod.shard_cache_key(other) \
            != autotune_mod.shard_cache_key(base)


def test_shard_cache_key_namespace_disjoint_from_sort_plans():
    p = build_shard_plan("data", 2, 64, "int32", _XLA)
    key = autotune_mod.shard_cache_key(p)
    assert key.startswith("shard|")
    assert "data" in key and "int32" in key


def test_shard_candidate_space_base_first_covers_all_axes():
    cands = autotune_mod.shard_candidate_space(_XLA, max_trials=16)
    assert cands[0].label == "base"
    assert cands[0].oversample == 8 and cands[0].pair_align == 8
    labels = [c.label for c in cands]
    assert any(l.startswith("strategy=") for l in labels)
    assert any(l.startswith("oversample=") for l in labels)
    assert any(l.startswith("pair_align=") for l in labels)
    assert len(set(labels)) == len(labels), "candidate space has dupes"
    # deterministic, every candidate pins plan="default" (no recursion)
    assert cands == autotune_mod.shard_candidate_space(_XLA, max_trials=16)
    assert all(c.cfg.plan == "default" for c in cands)


# ----------------------------------------------------------------------
# Serialization + file round-trip
# ----------------------------------------------------------------------


def test_shard_plan_dict_roundtrip_identical():
    p = build_shard_plan(
        ("data", "model"), 8, 1000, "float32",
        SortConfig(tile=256, s=16, direct_max=512, impl="xla",
                   descending=True),
        oversample=4, pair_align=128,
    )
    q = shard_plan_from_dict(json.loads(json.dumps(shard_plan_to_dict(p))))
    assert q == p and hash(q) == hash(p)
    assert q.run_plan == p.run_plan and q.bucket_plan == p.bucket_plan


def test_shard_plan_from_dict_rejects_bad_schema():
    d = shard_plan_to_dict(build_shard_plan("data", 2, 64, "int32", _XLA))
    d["schema"] = "shard_plan/v0"
    with pytest.raises(ValueError, match="shard_plan/v1"):
        shard_plan_from_dict(d)


def test_save_load_shard_plan_roundtrip(tmp_path):
    p = build_shard_plan("data", 4, 2048, "int32", _XLA)
    path = str(tmp_path / "shard.json")
    autotune_mod.save_shard_plan(p, path, meta={"note": "unit test"})
    assert autotune_mod.load_shard_plan(path) == p
    # the checked load make_sharded_sort performs for plan=<path>
    assert autotune_mod.load_shard_plan(
        path, axis="data", d=4, n_local=2048, dtype="int32", cfg=_XLA
    ) == p


def test_load_shard_plan_rejects_signature_mismatch(tmp_path):
    path = str(tmp_path / "shard.json")
    autotune_mod.save_shard_plan(
        build_shard_plan("data", 4, 2048, "int32", _XLA), path
    )
    with pytest.raises(ValueError, match="was built for"):
        autotune_mod.load_shard_plan(
            path, axis="data", d=8, n_local=1024, dtype="int32", cfg=_XLA
        )


# ----------------------------------------------------------------------
# Validation: field-naming ValueErrors at plan-build time
# ----------------------------------------------------------------------


@pytest.mark.parametrize("kw,match", [
    (dict(n_local=0), "n_local must be an int >= 1"),
    (dict(d=1), "d must be an int >= 2"),
    (dict(oversample=3), "oversample must be a power of two"),
    (dict(oversample=0), "oversample must be a power of two"),
    (dict(pair_align=4), "pair_align must be a power of two >= 8"),
    (dict(pair_align=12), "pair_align must be a power of two >= 8"),
])
def test_shard_geometry_validation_names_field(kw, match):
    base = dict(n_local=1024, d=4, oversample=8, pair_align=8)
    with pytest.raises(ValueError, match=match):
        shard_geometry(**{**base, **kw})


def test_build_shard_plan_validates_before_tracing():
    with pytest.raises(ValueError, match="oversample must be a power of two"):
        build_shard_plan("data", 4, 1024, "int32", _XLA, oversample=6)
    with pytest.raises(ValueError, match="pair_align"):
        build_shard_plan("data", 4, 1024, "int32", _XLA, pair_align=2)
