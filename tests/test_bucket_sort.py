"""Unit tests for the single-device GPU BUCKET SORT (Algorithm 1)."""

import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bucket_sort
from repro.core.sort_config import PAPER_CONFIG, SortConfig

CFG = SortConfig(tile=256, s=16, direct_max=512, impl="xla")


@pytest.mark.parametrize("n", [1, 2, 100, 511, 512, 513, 4096, 50_000])
@pytest.mark.parametrize(
    "dist", ["uniform", "dup", "equal", "sorted", "reverse", "zipf"]
)
def test_sort_all_distributions(rng, n, dist):
    if dist == "uniform":
        x = rng.integers(-(2**31), 2**31 - 1, n).astype(np.int32)
    elif dist == "dup":
        x = rng.integers(0, 7, n).astype(np.int32)
    elif dist == "equal":
        x = np.full(n, 42, np.int32)
    elif dist == "sorted":
        x = np.sort(rng.integers(0, 1000, n).astype(np.int32))
    elif dist == "reverse":
        x = np.sort(rng.integers(0, 1000, n).astype(np.int32))[::-1].copy()
    else:
        x = (rng.zipf(1.3, n) % 100000).astype(np.int32)
    out = np.asarray(bucket_sort.sort(jnp.asarray(x), CFG))
    np.testing.assert_array_equal(out, np.sort(x))


def test_sort_kv_permutes_values(rng):
    x = rng.integers(0, 100, 5000).astype(np.int32)
    vals = rng.normal(size=(5000, 3)).astype(np.float32)
    sk, sv = bucket_sort.sort_kv(jnp.asarray(x), jnp.asarray(vals), CFG)
    perm = np.argsort(x, kind="stable")
    np.testing.assert_array_equal(np.asarray(sk), x[perm])
    np.testing.assert_array_equal(np.asarray(sv), vals[perm])


def test_argsort_matches_numpy_stable(rng):
    x = rng.integers(0, 50, 20_000).astype(np.int32)
    perm = np.asarray(bucket_sort.argsort(jnp.asarray(x), CFG))
    np.testing.assert_array_equal(perm, np.argsort(x, kind="stable"))


def test_paper_config_sorts(rng):
    """PAPER_CONFIG mirrors the paper's geometry (2K tiles, s=64)."""
    x = rng.integers(-(2**31), 2**31 - 1, 300_000).astype(np.int32)
    out = np.asarray(bucket_sort.sort(jnp.asarray(x), PAPER_CONFIG))
    np.testing.assert_array_equal(out, np.sort(x))


def test_bfloat16_keys(rng):
    x = rng.normal(size=4000).astype(np.float32)
    xb = jnp.asarray(x, jnp.bfloat16)
    out = np.asarray(bucket_sort.sort(xb, CFG).astype(jnp.float32))
    ref = np.sort(np.asarray(xb.astype(jnp.float32)))
    np.testing.assert_array_equal(out, ref)


@pytest.mark.parametrize("dist", ["uniform", "dup", "equal"])
def test_gather_relocation_matches_scatter_reference(rng, dist):
    """The scatter-free relocation/compaction (DESIGN.md §4) must produce
    the IDENTICAL permutation as the legacy scatter formulation, and the
    fused sampling/ranking epilogues must not change it either."""
    n = 5000
    if dist == "uniform":
        x = rng.integers(-(2**31), 2**31 - 1, n).astype(np.int32)
    elif dist == "dup":
        x = rng.integers(0, 7, n).astype(np.int32)
    else:
        x = np.full(n, 42, np.int32)
    base = dataclasses.replace(
        CFG, relocation="scatter", fuse_sampling=False, fuse_ranking=False
    )
    want = np.asarray(bucket_sort.argsort(jnp.asarray(x), base))
    for cfg in [
        CFG,  # gather + fused (the default hot path)
        dataclasses.replace(CFG, relocation="scatter"),
        dataclasses.replace(CFG, fuse_sampling=False),
        dataclasses.replace(CFG, fuse_ranking=False),
    ]:
        got = np.asarray(bucket_sort.argsort(jnp.asarray(x), cfg))
        np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("block_rows", [1, 4])
def test_explicit_block_rows_sorts(rng, block_rows):
    cfg = dataclasses.replace(CFG, block_rows=block_rows)
    x = rng.integers(0, 100_000, 20_000).astype(np.int32)
    out = np.asarray(bucket_sort.sort(jnp.asarray(x), cfg))
    np.testing.assert_array_equal(out, np.sort(x))


def test_deterministic_across_runs(rng):
    """The paper's determinism claim: identical input => identical output
    AND identical permutation (no RNG anywhere in the pipeline)."""
    x = jnp.asarray(rng.integers(0, 10, 10_000).astype(np.int32))
    p1 = np.asarray(bucket_sort.argsort(x, CFG))
    p2 = np.asarray(bucket_sort.argsort(x, CFG))
    np.testing.assert_array_equal(p1, p2)


def test_sort_with_stats_direct_path_returns_empty_stats(rng):
    """Inputs within direct_max run zero bucket rounds: stats must be a
    well-defined EMPTY list (not an error), sort/perm still correct."""
    x = rng.integers(0, 100, CFG.direct_max).astype(np.int32)
    srt, perm, stats = bucket_sort.sort_with_stats(jnp.asarray(x), CFG)
    assert stats == []
    np.testing.assert_array_equal(np.asarray(srt), np.sort(x))
    np.testing.assert_array_equal(np.asarray(perm), np.argsort(x, kind="stable"))
    # trivial inputs too
    for n in (0, 1):
        srt, perm, stats = bucket_sort.sort_with_stats(
            jnp.asarray(x[:n]), CFG
        )
        assert stats == [] and srt.shape == (n,) and perm.shape == (n,)
    # and the batched variant
    xb = rng.integers(0, 100, (3, CFG.direct_max // 2)).astype(np.int32)
    srt, perm, stats = bucket_sort.sort_batched_with_stats(jnp.asarray(xb), CFG)
    assert stats == []
    np.testing.assert_array_equal(np.asarray(srt), np.sort(xb, axis=1))


def test_batched_stats_bucket_bound_adversarial_rows(rng):
    """The capacity bound holds PER ROW: an all-duplicates row next to a
    uniform row (plus sorted/reverse/zipf rows) must keep every round's
    max bucket fill <= capacity, for every bucket of every row."""
    n = 4 * CFG.direct_max
    rows = np.stack([
        np.full(n, 42, np.int32),  # all-dup: worst case for splitters
        rng.integers(-(2**31), 2**31 - 1, n).astype(np.int32),  # uniform
        np.sort(rng.integers(0, 1000, n).astype(np.int32)),  # presorted
        np.sort(rng.integers(0, 1000, n).astype(np.int32))[::-1],  # reverse
        (rng.zipf(1.3, n) % 100000).astype(np.int32),  # heavy skew
    ])
    srt, perm, stats = bucket_sort.sort_batched_with_stats(
        jnp.asarray(rows), CFG
    )
    assert len(stats) >= 1
    for stt in stats:
        totals = np.asarray(stt["totals"])  # (rows_at_level, s_round)
        assert totals.min() >= 0
        assert totals.max() <= stt["capacity"], (totals.max(), stt["capacity"])
        assert int(np.asarray(stt["max_within"])) < stt["capacity"]
    np.testing.assert_array_equal(np.asarray(srt), np.sort(rows, axis=1))
    np.testing.assert_array_equal(
        np.asarray(perm), np.argsort(rows, axis=1, kind="stable")
    )
