"""Pallas kernels (interpret=True) vs pure-jnp oracles, swept over
shapes and dtypes (the per-kernel allclose contract)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import bitonic, ops, ref


def _keys(rng, m, t, dtype):
    """Adversarial key matrix: duplicates everywhere; floats get NaN,
    +/-0.0 and +/-inf sprinkled in (total-order canonicalization)."""
    if dtype == np.float32:
        k = rng.normal(size=(m, t)).astype(dtype)
        flat = k.reshape(-1)
        n_special = max(flat.size // 16, 8)
        pos = rng.choice(flat.size, size=n_special, replace=False)
        specials = np.array([np.nan, -0.0, 0.0, np.inf, -np.inf], dtype)
        flat[pos] = specials[rng.integers(0, len(specials), n_special)]
        return flat.reshape(m, t)
    return rng.integers(0, 97, size=(m, t)).astype(dtype)  # duplicates


@pytest.mark.parametrize("m,t", [(1, 128), (3, 256), (8, 512), (2, 1024)])
@pytest.mark.parametrize("dtype", [np.int32, np.uint32, np.float32])
def test_bitonic_sort_tiles(rng, m, t, dtype):
    k = _keys(rng, m, t, dtype)
    ku = ops.to_sortable(jnp.asarray(k))
    v = jnp.tile(jnp.arange(t, dtype=jnp.int32), (m, 1))
    sk_p, sv_p = ops.sort_tiles(ku, v, impl="pallas", interpret=True)
    sk_r, sv_r = ref.sort_tiles_kv(ku, v)
    np.testing.assert_array_equal(np.asarray(sk_p), np.asarray(sk_r))
    np.testing.assert_array_equal(np.asarray(sv_p), np.asarray(sv_r))


@pytest.mark.parametrize("block_rows", [1, 4, 8])
@pytest.mark.parametrize("t", [256, 1024, 4096])
@pytest.mark.parametrize("dtype", [np.uint32, np.int32, np.float32])
def test_blocked_sort_tiles_bitexact(rng, block_rows, t, dtype):
    """Row-blocked kernel vs ref.py oracle: bit-exact on every dtype,
    including NaN / -0.0 floats (via canonical keys) and duplicates."""
    m = 8  # divisible by every block_rows under test
    k = _keys(rng, m, t, dtype)
    ku = ops.to_sortable(jnp.asarray(k))
    v = jnp.tile(jnp.arange(t, dtype=jnp.int32), (m, 1))
    sk_p, sv_p = bitonic.sort_tiles_kv(
        ku, v, block_rows=block_rows, interpret=True
    )
    sk_r, sv_r = ref.sort_tiles_kv(ku, v)
    np.testing.assert_array_equal(np.asarray(sk_p), np.asarray(sk_r))
    np.testing.assert_array_equal(np.asarray(sv_p), np.asarray(sv_r))
    # bit-exact in the canonical total-order domain (covers NaN payloads
    # and the -0.0 < +0.0 distinction that np.sort on floats erases)
    np.testing.assert_array_equal(
        np.asarray(sk_p), np.sort(np.asarray(ku), axis=-1)
    )


@pytest.mark.parametrize("block_rows", [1, 4, 8])
def test_blocked_sort_all_duplicates(block_rows):
    """All-equal keys: payload order (stability) is the whole contract."""
    m, t = 8, 256
    ku = jnp.full((m, t), jnp.uint32(42))
    v = jnp.tile(jnp.arange(t, dtype=jnp.int32)[::-1], (m, 1))
    sk, sv = bitonic.sort_tiles_kv(ku, v, block_rows=block_rows, interpret=True)
    np.testing.assert_array_equal(np.asarray(sk), np.asarray(ku))
    np.testing.assert_array_equal(
        np.asarray(sv), np.tile(np.arange(t, dtype=np.int32), (m, 1))
    )


@pytest.mark.parametrize("block_rows", [1, 4])
@pytest.mark.parametrize("t,s", [(256, 16), (1024, 64)])
def test_fused_sample_extraction(rng, block_rows, t, s):
    """sort_tiles_sample == sort + strided sample slice of the oracle."""
    m = 8
    k = rng.integers(0, 10_000, size=(m, t)).astype(np.int32)
    ku = ops.to_sortable(jnp.asarray(k))
    v = jnp.tile(jnp.arange(t, dtype=jnp.int32), (m, 1))
    sk_p, sv_p, sampk_p, sampv_p = ops.sort_tiles_sample(
        ku, v, num_samples=s, impl="pallas", interpret=True,
        block_rows=block_rows,
    )
    sk_r, sv_r, sampk_r, sampv_r = ref.sort_tiles_sample_kv(
        ku, v, num_samples=s
    )
    for got, want in [(sk_p, sk_r), (sv_p, sv_r), (sampk_p, sampk_r),
                      (sampv_p, sampv_r)]:
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # samples are the paper's equidistant positions (j+1)*T/s - 1
    idx = (np.arange(1, s + 1) * (t // s)) - 1
    np.testing.assert_array_equal(
        np.asarray(sampk_p), np.asarray(sk_r)[:, idx]
    )


def test_bitonic_stability(rng):
    k = rng.integers(0, 3, size=(4, 256)).astype(np.int32)
    ku = ops.to_sortable(jnp.asarray(k))
    v = jnp.tile(jnp.arange(256, dtype=jnp.int32), (4, 1))
    _, sv = ops.sort_tiles(ku, v, impl="pallas", interpret=True)
    for i in range(4):
        np.testing.assert_array_equal(
            np.asarray(sv[i]), np.argsort(k[i], kind="stable")
        )


@pytest.mark.parametrize("m,t,s", [(2, 256, 7), (4, 512, 15), (1, 128, 1)])
def test_splitter_ranks(rng, m, t, s):
    k = rng.integers(0, 1000, size=(m, t)).astype(np.int32)
    ku = ops.to_sortable(jnp.asarray(k))
    v = jnp.tile(jnp.arange(t, dtype=jnp.int32), (m, 1))
    spk = ops.to_sortable(
        jnp.asarray(np.sort(rng.integers(0, 1000, size=(m, s)), axis=1).astype(np.int32))
    )
    spv = jnp.zeros((m, s), jnp.int32)
    r_p = ops.splitter_ranks(ku, v, spk, spv, impl="pallas", interpret=True)
    r_r = ref.splitter_ranks(ku, v, spk, spv)
    np.testing.assert_array_equal(np.asarray(r_p), np.asarray(r_r))
    # oracle vs numpy searchsorted per row
    for i in range(m):
        expect = np.searchsorted(np.sort(k[i]), np.sort(
            np.asarray(ops.from_sortable(spk[i], jnp.int32))), side="left")
        sk = np.sort(k[i])
        # ranks computed against the unsorted tile equal counts of x < sp
        got = np.asarray(r_r[i])
        manual = [(k[i] < spv_i).sum() for spv_i in
                  np.asarray(ops.from_sortable(spk[i], jnp.int32))]
        np.testing.assert_array_equal(got, manual)


@pytest.mark.parametrize("block_rows", [None, 1, 4])
@pytest.mark.parametrize("m,t,s", [(4, 256, 7), (8, 512, 15)])
def test_splitter_partition_fused(rng, block_rows, m, t, s):
    """Fused Step 6+7 epilogue vs oracle: ranks and bucket counts."""
    k = rng.integers(0, 1000, size=(m, t)).astype(np.int32)
    ku = ops.to_sortable(jnp.asarray(k))
    v = jnp.tile(jnp.arange(t, dtype=jnp.int32), (m, 1))
    spk = ops.to_sortable(jnp.asarray(
        np.sort(rng.integers(0, 1000, size=(m, s)), axis=1).astype(np.int32)))
    spv = jnp.zeros((m, s), jnp.int32)
    r_p, c_p = ops.splitter_partition(
        ku, v, spk, spv, impl="pallas", interpret=True, block_rows=block_rows
    )
    r_r, c_r = ref.splitter_partition(ku, v, spk, spv)
    np.testing.assert_array_equal(np.asarray(r_p), np.asarray(r_r))
    np.testing.assert_array_equal(np.asarray(c_p), np.asarray(c_r))
    assert (np.asarray(c_p).sum(axis=1) == t).all()  # counts partition T


@pytest.mark.parametrize("r,c,k", [(8, 64, 4), (256, 128, 8), (64, 32, 32)])
@pytest.mark.parametrize("dtype", [np.float32, np.int32])
def test_topk(rng, r, c, k, dtype):
    if dtype == np.float32:
        x = rng.normal(size=(r, c)).astype(dtype)
    else:
        x = rng.integers(-50, 50, size=(r, c)).astype(dtype)
    xa = jnp.asarray(x)
    tv, ti = ops.topk(xa, k, impl="pallas", interpret=True)
    lv, li = jax.lax.top_k(xa, k)
    np.testing.assert_array_equal(np.asarray(ti), np.asarray(li))
    np.testing.assert_allclose(np.asarray(tv, np.float64), np.asarray(lv, np.float64))


def test_topk_ties(rng):
    x = jnp.asarray(rng.integers(0, 3, size=(32, 64)).astype(np.float32))
    tv, ti = ops.topk(x, 8, impl="pallas", interpret=True)
    lv, li = jax.lax.top_k(x, 8)
    np.testing.assert_array_equal(np.asarray(ti), np.asarray(li))


def test_float_canonicalization_total_order():
    f = np.array([np.nan, np.inf, -np.inf, -0.0, 0.0, 1.5, -1.5, 1e-39,
                  -1e-39, 3.4e38], dtype=np.float32)
    u = ops.to_sortable(jnp.asarray(f))
    back = np.asarray(ops.from_sortable(u, jnp.float32))
    same = (back == f) | (np.isnan(back) & np.isnan(f))
    assert same.all()
    order = np.argsort(np.asarray(u))
    vals = f[order]
    finite = vals[np.isfinite(vals)]
    assert (np.diff(finite) >= 0).all()


def test_sortable_roundtrip_int():
    x = jnp.asarray(np.array([-(2**31), -1, 0, 1, 2**31 - 1], np.int32))
    u = ops.to_sortable(x)
    assert (np.diff(np.asarray(u).astype(np.uint64)) > 0).all()
    np.testing.assert_array_equal(np.asarray(ops.from_sortable(u, jnp.int32)), np.asarray(x))
