"""Pallas kernels (interpret=True) vs pure-jnp oracles, swept over
shapes and dtypes (the per-kernel allclose contract)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


@pytest.mark.parametrize("m,t", [(1, 128), (3, 256), (8, 512), (2, 1024)])
@pytest.mark.parametrize("dtype", [np.int32, np.uint32, np.float32])
def test_bitonic_sort_tiles(rng, m, t, dtype):
    if dtype == np.float32:
        k = rng.normal(size=(m, t)).astype(dtype)
    else:
        k = rng.integers(0, 97, size=(m, t)).astype(dtype)  # duplicates
    ku = ops.to_sortable(jnp.asarray(k))
    v = jnp.tile(jnp.arange(t, dtype=jnp.int32), (m, 1))
    sk_p, sv_p = ops.sort_tiles(ku, v, impl="pallas", interpret=True)
    sk_r, sv_r = ref.sort_tiles_kv(ku, v)
    np.testing.assert_array_equal(np.asarray(sk_p), np.asarray(sk_r))
    np.testing.assert_array_equal(np.asarray(sv_p), np.asarray(sv_r))
    back = np.asarray(ops.from_sortable(sk_p, jnp.dtype(dtype)))
    np.testing.assert_array_equal(back, np.sort(k, axis=-1))


def test_bitonic_stability(rng):
    k = rng.integers(0, 3, size=(4, 256)).astype(np.int32)
    ku = ops.to_sortable(jnp.asarray(k))
    v = jnp.tile(jnp.arange(256, dtype=jnp.int32), (4, 1))
    _, sv = ops.sort_tiles(ku, v, impl="pallas", interpret=True)
    for i in range(4):
        np.testing.assert_array_equal(
            np.asarray(sv[i]), np.argsort(k[i], kind="stable")
        )


@pytest.mark.parametrize("m,t,s", [(2, 256, 7), (4, 512, 15), (1, 128, 1)])
def test_splitter_ranks(rng, m, t, s):
    k = rng.integers(0, 1000, size=(m, t)).astype(np.int32)
    ku = ops.to_sortable(jnp.asarray(k))
    v = jnp.tile(jnp.arange(t, dtype=jnp.int32), (m, 1))
    spk = ops.to_sortable(
        jnp.asarray(np.sort(rng.integers(0, 1000, size=(m, s)), axis=1).astype(np.int32))
    )
    spv = jnp.zeros((m, s), jnp.int32)
    r_p = ops.splitter_ranks(ku, v, spk, spv, impl="pallas", interpret=True)
    r_r = ref.splitter_ranks(ku, v, spk, spv)
    np.testing.assert_array_equal(np.asarray(r_p), np.asarray(r_r))
    # oracle vs numpy searchsorted per row
    for i in range(m):
        expect = np.searchsorted(np.sort(k[i]), np.sort(
            np.asarray(ops.from_sortable(spk[i], jnp.int32))), side="left")
        sk = np.sort(k[i])
        # ranks computed against the unsorted tile equal counts of x < sp
        got = np.asarray(r_r[i])
        manual = [(k[i] < spv_i).sum() for spv_i in
                  np.asarray(ops.from_sortable(spk[i], jnp.int32))]
        np.testing.assert_array_equal(got, manual)


@pytest.mark.parametrize("r,c,k", [(8, 64, 4), (256, 128, 8), (64, 32, 32)])
@pytest.mark.parametrize("dtype", [np.float32, np.int32])
def test_topk(rng, r, c, k, dtype):
    if dtype == np.float32:
        x = rng.normal(size=(r, c)).astype(dtype)
    else:
        x = rng.integers(-50, 50, size=(r, c)).astype(dtype)
    xa = jnp.asarray(x)
    tv, ti = ops.topk(xa, k, impl="pallas", interpret=True)
    lv, li = jax.lax.top_k(xa, k)
    np.testing.assert_array_equal(np.asarray(ti), np.asarray(li))
    np.testing.assert_allclose(np.asarray(tv, np.float64), np.asarray(lv, np.float64))


def test_topk_ties(rng):
    x = jnp.asarray(rng.integers(0, 3, size=(32, 64)).astype(np.float32))
    tv, ti = ops.topk(x, 8, impl="pallas", interpret=True)
    lv, li = jax.lax.top_k(x, 8)
    np.testing.assert_array_equal(np.asarray(ti), np.asarray(li))


def test_float_canonicalization_total_order():
    f = np.array([np.nan, np.inf, -np.inf, -0.0, 0.0, 1.5, -1.5, 1e-39,
                  -1e-39, 3.4e38], dtype=np.float32)
    u = ops.to_sortable(jnp.asarray(f))
    back = np.asarray(ops.from_sortable(u, jnp.float32))
    same = (back == f) | (np.isnan(back) & np.isnan(f))
    assert same.all()
    order = np.argsort(np.asarray(u))
    vals = f[order]
    finite = vals[np.isfinite(vals)]
    assert (np.diff(finite) >= 0).all()


def test_sortable_roundtrip_int():
    x = jnp.asarray(np.array([-(2**31), -1, 0, 1, 2**31 - 1], np.int32))
    u = ops.to_sortable(x)
    assert (np.diff(np.asarray(u).astype(np.uint64)) > 0).all()
    np.testing.assert_array_equal(np.asarray(ops.from_sortable(u, jnp.int32)), np.asarray(x))
