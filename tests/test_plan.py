"""Planner/executor split: plan IR purity, planner-path conformance,
compile-count discipline, the persistent plan cache, and the
SortConfig construction-time validation.

The conformance slice here is the CI plan-cache smoke leg's 16-cell
matrix (dtype x impl x size x relocation through ``sort_planned``);
the full 807-cell harness in test_conformance.py exercises the same
plan-driven executor through the public entry points.
"""

import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import autotune as autotune_mod
from repro.core import bucket_sort, partial_sort
from repro.core.plan import (
    build_plan,
    build_topk_plan,
    build_words_plan,
    config_fingerprint,
    plan_from_dict,
    plan_json,
    plan_to_dict,
)
from repro.core.sort_config import SortConfig

_XLA = SortConfig(tile=256, s=16, direct_max=512, impl="xla")
_PAL = SortConfig(tile=128, s=8, direct_max=256, impl="pallas", interpret=True)


# ----------------------------------------------------------------------
# SortConfig construction-time validation (ValueError naming the field)
# ----------------------------------------------------------------------


@pytest.mark.parametrize(
    "kw,field",
    [
        (dict(tile=3000), "tile"),
        (dict(tile=0), "tile"),
        (dict(s=48, tile=4096), "s"),
        (dict(s=8192, tile=4096), "SortConfig.s"),
        (dict(block_rows=12), "block_rows"),
        (dict(row_pad=6), "row_pad"),
        (dict(direct_max=1024, tile=4096), "direct_max"),
        (dict(impl="cuda"), "impl"),
        (dict(relocation="teleport"), "relocation"),
        (dict(plan=""), "plan"),
    ],
)
def test_config_validation_names_field(kw, field):
    with pytest.raises(ValueError, match=field):
        SortConfig(**kw)


def test_config_valid_knobs_accepted():
    SortConfig(tile=1024, s=64, direct_max=2048, block_rows=16, row_pad=4,
               plan="autotune")


# ----------------------------------------------------------------------
# build_plan: pure, deterministic, structurally sound
# ----------------------------------------------------------------------


@pytest.mark.parametrize("length", [100, 513, 5000, 100_000])
@pytest.mark.parametrize("dtype", ["int32", "float64"])
def test_build_plan_deterministic(length, dtype):
    a = build_plan(length, dtype, _XLA)
    b = build_plan(length, dtype, _XLA)
    assert a == b and hash(a) == hash(b)
    # byte-identical canonical serialization
    assert plan_json(a) == plan_json(b)


def test_build_plan_property_deterministic_and_bounded():
    hypothesis = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=40, deadline=None)
    @given(
        length=st.integers(min_value=1, max_value=300_000),
        rows=st.integers(min_value=1, max_value=64),
        tile_log=st.integers(min_value=7, max_value=12),
        s_log=st.integers(min_value=1, max_value=6),
    )
    def prop(length, rows, tile_log, s_log):
        tile = 2 ** tile_log
        s = min(2 ** s_log, tile)
        cfg = SortConfig(tile=tile, s=s, direct_max=2 * tile, impl="xla")
        a = build_plan(length, "int32", cfg, rows=rows)
        b = build_plan(length, "int32", cfg, rows=rows)
        assert plan_json(a) == plan_json(b)
        # structural invariants: every node's geometry is self-consistent
        node = a.root
        while node is not None:
            assert node.rows >= 1 and node.lp >= node.length
            if node.kind == "direct":
                assert node.lp & (node.lp - 1) == 0
                break
            assert node.lp == node.m * node.tile
            assert 2 <= node.s_round <= node.s
            # the paper's capacity bound, lane-aligned
            assert node.cap >= node.lp // node.s_round + node.lp // node.s
            assert node.sample_plan.length == node.m * node.s
            assert node.bucket_plan.rows == node.rows * node.s_round
            assert node.bucket_plan.length == node.cap
            node = node.bucket_plan

    prop()


def test_plan_fingerprint_ignores_plan_field():
    a = config_fingerprint(_XLA)
    b = config_fingerprint(dataclasses.replace(_XLA, plan="autotune"))
    c = config_fingerprint(dataclasses.replace(_XLA, s=32))
    assert a == b
    assert a != c


def test_words_plan_matches_dtype_plan_geometry():
    p32 = build_plan(5000, "int32", _XLA)
    pw = build_words_plan(5000, 1, _XLA)
    assert pw.root == p32.root  # same geometry, codec-free identity
    p64 = build_plan(5000, "int64", _XLA)
    assert build_words_plan(5000, 2, _XLA).root == p64.root


def test_plan_dict_roundtrip_identical():
    for cfg in (_XLA, _PAL, dataclasses.replace(_XLA, descending=True)):
        p = build_plan(40_000, "float32", cfg, rows=4, pad_rows=True)
        d = plan_to_dict(p)
        # the dict is JSON-clean
        rt = plan_from_dict(json.loads(json.dumps(d)))
        assert rt == p and hash(rt) == hash(p)


def test_plan_from_dict_rejects_bad_schema():
    d = plan_to_dict(build_plan(100, "int32", _XLA))
    d["schema"] = "bogus/v9"
    with pytest.raises(ValueError, match="schema"):
        plan_from_dict(d)


def test_degenerate_config_raises_clear_error():
    # s == tile never shrinks the sample array: the builder must say so
    # instead of recursing forever.
    cfg = SortConfig(tile=128, s=128, direct_max=128, impl="xla")
    with pytest.raises(ValueError, match="depth"):
        build_plan(1000, "int32", cfg)


# ----------------------------------------------------------------------
# Planner-path conformance: the CI smoke slice (16 cells)
# ----------------------------------------------------------------------


@pytest.mark.parametrize("reloc", ["gather", "scatter"])
@pytest.mark.parametrize("n", [255, 1500])
@pytest.mark.parametrize("dtype", ["int32", "float32"])
@pytest.mark.parametrize("cfg0", [_XLA, _PAL], ids=["xla", "pallas"])
def test_planner_conformance(cfg0, dtype, n, reloc, rng):
    cfg = dataclasses.replace(cfg0, relocation=reloc)
    if dtype == "int32":
        x = rng.integers(-(2**31), 2**31 - 1, n).astype(np.int32)
    else:
        x = rng.standard_normal(n).astype(np.float32)
        x[:4] = [np.nan, np.inf, -np.inf, 0.0]
    plan = build_plan(n, dtype, cfg)
    got = bucket_sort.sort_planned(jnp.asarray(x), plan)
    want = jnp.sort(jnp.asarray(x))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_sort_planned_validates_signature():
    plan = build_plan(100, "int32", _XLA)
    with pytest.raises(ValueError, match="match"):
        bucket_sort.sort_planned(jnp.zeros(101, jnp.int32), plan)
    with pytest.raises(ValueError, match="match"):
        bucket_sort.sort_planned(jnp.zeros(100, jnp.float32), plan)


def test_sort_planned_batched_and_descending(rng):
    xs = rng.integers(0, 50, (5, 700)).astype(np.int32)
    cfg = dataclasses.replace(_XLA, descending=True)
    plan = build_plan(700, "int32", cfg, rows=5, pad_rows=True)
    got = bucket_sort.sort_planned(jnp.asarray(xs), plan)
    np.testing.assert_array_equal(
        np.asarray(got), -np.sort(-xs, axis=1, kind="stable")
    )


def test_topk_plan_matches_lax_topk(rng):
    x = rng.standard_normal(9000).astype(np.float32)
    tplan = build_topk_plan(9000, 7, jnp.float32, _XLA)
    assert tplan.lp % tplan.tile == 0 and tplan.ccap >= 7
    v, i = partial_sort.topk(jnp.asarray(x), 7, _XLA)
    lv, li = jax.lax.top_k(jnp.asarray(x), 7)
    np.testing.assert_array_equal(np.asarray(i), np.asarray(li))
    np.testing.assert_array_equal(np.asarray(v), np.asarray(lv))


# ----------------------------------------------------------------------
# Compile-count discipline: same signature traces once; plan-cache hits
# trace zero times
# ----------------------------------------------------------------------


def test_same_signature_traces_once(rng):
    cfg = dataclasses.replace(_XLA, tile=128, s=8, direct_max=256)
    x = jnp.asarray(rng.integers(0, 10_000, 1777).astype(np.int32))
    bucket_sort.sort(x, cfg)  # may trace (fresh signature)
    t0 = bucket_sort.trace_count()
    for _ in range(3):
        bucket_sort.sort(x, cfg)
    assert bucket_sort.trace_count() == t0, "same-signature sort retraced"


def test_same_signature_batched_traces_once(rng):
    cfg = dataclasses.replace(_XLA, tile=128, s=8, direct_max=256)
    xs = jnp.asarray(rng.integers(0, 10_000, (3, 911)).astype(np.int32))
    bucket_sort.sort_batched(xs, cfg)
    t0 = bucket_sort.trace_count()
    for _ in range(3):
        bucket_sort.sort_batched(xs, cfg)
        bucket_sort.argsort_batched(xs, cfg)  # same plan, same executable
    assert bucket_sort.trace_count() == t0, "same-signature batch retraced"


def test_plan_cache_hit_zero_retrace(tmp_path, monkeypatch, rng):
    monkeypatch.setenv("REPRO_SORT_PLAN_CACHE", str(tmp_path / "plans.json"))
    cfg = SortConfig(tile=128, s=8, direct_max=256, impl="xla",
                     plan="autotune")
    x = jnp.asarray(rng.integers(0, 10_000, 2333).astype(np.int32))
    y = bucket_sort.sort(x, cfg)  # miss: tunes, saves, compiles winner
    np.testing.assert_array_equal(np.asarray(y), np.sort(np.asarray(x)))
    assert (tmp_path / "plans.json").exists()
    # Forget the in-process memo: the next call must go to DISK, reload
    # an identical plan, and hit the jit cache — zero retraces.
    autotune_mod.clear_memo()
    t0 = bucket_sort.trace_count()
    y2 = bucket_sort.sort(x, cfg)
    assert bucket_sort.trace_count() == t0, "plan-cache hit retraced"
    np.testing.assert_array_equal(np.asarray(y2), np.asarray(y))


def test_plan_cache_roundtrip_identical(tmp_path):
    """CI plan-cache smoke: build -> save -> reload -> identical plan."""
    p = build_plan(123_456, "float32", _PAL, rows=3, pad_rows=True)
    path = str(tmp_path / "plan.json")
    autotune_mod.save_plan(p, path, meta={"source": "test"})
    rt = autotune_mod.load_plan(
        path, length=123_456, dtype="float32", cfg=_PAL, rows=3
    )
    assert rt == p and hash(rt) == hash(p)
    assert plan_json(rt) == plan_json(p)


def test_load_plan_rejects_signature_mismatch(tmp_path):
    p = build_plan(1000, "int32", _XLA)
    path = str(tmp_path / "plan.json")
    autotune_mod.save_plan(p, path)
    with pytest.raises(ValueError, match="built for"):
        autotune_mod.load_plan(path, length=2000, dtype="int32", cfg=_XLA)
    with pytest.raises(ValueError, match="built for"):
        autotune_mod.load_plan(path, length=1000, dtype="float32", cfg=_XLA)


def test_cfg_plan_path_roundtrip(tmp_path, rng):
    x = rng.integers(0, 1000, 3000).astype(np.int32)
    p = build_plan(3000, "int32", _XLA)
    path = str(tmp_path / "plan.json")
    autotune_mod.save_plan(p, path)
    cfg = dataclasses.replace(_XLA, plan=path)
    got = bucket_sort.sort(jnp.asarray(x), cfg)
    np.testing.assert_array_equal(np.asarray(got), np.sort(x))


def test_autotune_winner_not_slower_than_default():
    """Acceptance: the tuned plan's measured time <= the default
    config's (the default is candidate 0 of the search space)."""
    res = autotune_mod.autotune(
        50_000, "int32", _XLA, max_trials=6, repeats=2
    )
    assert res.best_us <= res.default_us
    assert res.trials and res.trials[0].label == "base"
    assert res.speedup >= 1.0


def test_autotune_candidate_space_valid_and_deterministic():
    cands = autotune_mod.candidate_space(_XLA, 100_000, max_trials=16)
    cands2 = autotune_mod.candidate_space(_XLA, 100_000, max_trials=16)
    assert [c.label for c in cands] == [c.label for c in cands2]
    assert 2 <= len(cands) <= 16
    assert cands[0].cfg.tile == _XLA.tile and cands[0].cfg.s == _XLA.s
    for c in cands:
        assert c.cfg.s <= c.cfg.tile and c.cfg.tile % c.cfg.s == 0
