"""Guarded execution (core/guard.py): checked modes, invariant checks,
degradation machinery, and the checked-mode end-to-end contract —
``check='bounds'|'full'`` must be output-invariant on healthy runs and
raise a structured SortRuntimeError on doctored ones (DESIGN.md §11)."""
import dataclasses
import json
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import autotune, bucket_sort, faults, guard, partial_sort
from repro.core.key_codec import codec_for
from repro.core.plan import build_plan, config_fingerprint
from repro.core.sort_config import SortConfig

CFG = SortConfig(tile=256, s=16, direct_max=512, impl="xla")


@pytest.fixture(autouse=True)
def _clean_state():
    faults.reset()
    guard.clear_degradation_log()
    yield
    faults.reset()
    guard.clear_degradation_log()


def _cfg(check="off", **kw):
    return dataclasses.replace(CFG, check=check, **kw)


# ----------------------------------------------------------------------
# Knob validation + cache identity
# ----------------------------------------------------------------------


def test_check_knob_validated():
    with pytest.raises(ValueError, match="check"):
        SortConfig(check="bogus")
    for mode in guard.CHECK_MODES:
        SortConfig(check=mode)
    with pytest.raises(ValueError):
        guard.validate_check("nope")


def test_fingerprint_ignores_check():
    """Checked and unchecked configs must share plan-cache entries."""
    assert config_fingerprint(_cfg("off")) == config_fingerprint(_cfg("full"))
    assert config_fingerprint(_cfg("off")) == config_fingerprint(_cfg("bounds"))


def test_invalid_check_rejected_at_entry(rng):
    x = jnp.asarray(rng.integers(0, 100, 10).astype(np.int32))
    cfg = dataclasses.replace(CFG)
    object.__setattr__(cfg, "check", "sideways")  # bypass __post_init__
    with pytest.raises(ValueError, match="check"):
        bucket_sort.sort(x, cfg)


# ----------------------------------------------------------------------
# Checked modes are output-invariant on healthy runs
# ----------------------------------------------------------------------


@pytest.mark.parametrize("dtype", [np.int32, np.float32, np.uint16])
@pytest.mark.parametrize("check", ["bounds", "full"])
def test_checked_sort_matches_unchecked(rng, dtype, check):
    if np.issubdtype(dtype, np.floating):
        x = jnp.asarray(rng.normal(size=4000).astype(dtype))
    else:
        info = np.iinfo(dtype)
        x = jnp.asarray(
            rng.integers(info.min, info.max, 4000).astype(dtype))
    base = bucket_sort.sort(x, _cfg("off"))
    out = bucket_sort.sort(x, _cfg(check))
    np.testing.assert_array_equal(np.asarray(out), np.asarray(base))


@pytest.mark.parametrize("check", ["bounds", "full"])
def test_checked_batched_and_segmented(rng, check):
    xs = jnp.asarray(rng.integers(0, 10**6, (4, 1500)).astype(np.int32))
    np.testing.assert_array_equal(
        np.asarray(bucket_sort.sort_batched(xs, _cfg(check))),
        np.sort(np.asarray(xs), axis=1))
    perm = bucket_sort.argsort_batched(xs, _cfg(check))
    np.testing.assert_array_equal(
        np.take_along_axis(np.asarray(xs), np.asarray(perm), axis=1),
        np.sort(np.asarray(xs), axis=1))
    x = jnp.asarray(rng.integers(0, 10**6, 3000).astype(np.int32))
    offs = [0, 700, 700, 2048, 3000]
    seg = bucket_sort.segment_sort(x, offs, _cfg(check))
    ref = np.asarray(x).copy()
    for a, b in zip(offs[:-1], offs[1:]):
        ref[a:b] = np.sort(ref[a:b])
    np.testing.assert_array_equal(np.asarray(seg), ref)


@pytest.mark.parametrize("check", ["bounds", "full"])
def test_checked_sort_with_stats(rng, check):
    x = jnp.asarray(rng.integers(0, 10**6, 3000).astype(np.int32))
    srt, perm, stats = bucket_sort.sort_with_stats(x, _cfg(check))
    np.testing.assert_array_equal(np.asarray(srt), np.sort(np.asarray(x)))
    assert len(stats) >= 1
    for st in stats:
        assert int(np.asarray(st["totals"]).max()) <= int(st["capacity"])


@pytest.mark.parametrize("check", ["bounds", "full"])
def test_checked_topk(rng, check):
    x = jnp.asarray(rng.normal(size=3000).astype(np.float32))
    v, i = partial_sort.topk(x, 17, _cfg(check))
    rv, ri = jax.lax.top_k(x, 17)
    np.testing.assert_array_equal(np.asarray(v), np.asarray(rv))
    np.testing.assert_array_equal(np.asarray(i), np.asarray(ri))
    xb = jnp.asarray(rng.normal(size=(3, 2000)).astype(np.float32))
    vb, ib = partial_sort.topk_batched(xb, 9, _cfg(check))
    rvb, rib = jax.lax.top_k(xb, 9)
    np.testing.assert_array_equal(np.asarray(vb), np.asarray(rvb))
    np.testing.assert_array_equal(np.asarray(ib), np.asarray(rib))


# ----------------------------------------------------------------------
# A doctored plan must raise a structured error naming the plan node
# ----------------------------------------------------------------------


def _doctored_plan(x):
    """A plan whose declared capacity is consistently shrunk below the
    true bucket fills: execution keeps its static shapes, but the
    measured fills violate the (doctored) bound."""
    plan = bucket_sort.resolve_plan(x.shape[0], x.dtype, CFG)
    root = plan.root
    assert root.kind == "bucket" and root.cap > 128
    child = root.bucket_plan
    bad_child = dataclasses.replace(
        child, length=128, lp=max(128, child.lp // (child.length // 128 or 1))
    )
    if bad_child.kind == "direct":
        bad_child = dataclasses.replace(bad_child, lp=128)
    bad_root = dataclasses.replace(root, cap=128, bucket_plan=bad_child)
    return dataclasses.replace(plan, root=bad_root)


def test_doctored_plan_raises_structured_error(rng):
    x = jnp.asarray(rng.integers(0, 10**9, 4096).astype(np.int32))
    bad = _doctored_plan(x)
    with pytest.raises(guard.SortRuntimeError) as ei:
        bucket_sort.sort_planned(x, bad, check="bounds")
    err = ei.value
    assert "bucket" in err.site and "cap=128" in err.site
    assert err.invariant == "bucket_fill <= cap"
    assert "128" in err.detail


def test_sort_planned_check_passes_on_healthy_plan(rng):
    x = jnp.asarray(rng.integers(0, 10**9, 4096).astype(np.int32))
    plan = bucket_sort.resolve_plan(x.shape[0], x.dtype, CFG)
    out = bucket_sort.sort_planned(x, plan, check="full")
    np.testing.assert_array_equal(np.asarray(out), np.sort(np.asarray(x)))


# ----------------------------------------------------------------------
# Unit tests of the invariant checkers on synthetically corrupt data
# ----------------------------------------------------------------------


def _plan_and_stats(rng):
    x = jnp.asarray(rng.integers(0, 10**6, 3000).astype(np.int32))
    plan = bucket_sort.resolve_plan(x.shape[0], x.dtype, CFG)
    _, _, stats = bucket_sort.sort_with_stats(x, CFG)
    return x, plan, stats


def test_check_bounds_detects_corruption(rng):
    x, plan, stats = _plan_and_stats(rng)
    guard.check_bounds(plan, stats)  # healthy: no raise
    bad = [dict(st) for st in stats]
    bad[0]["totals"] = np.asarray(bad[0]["totals"]).copy()
    bad[0]["totals"][0, 0] = int(bad[0]["capacity"]) + 1
    with pytest.raises(guard.SortRuntimeError, match="bucket_fill"):
        guard.check_bounds(plan, bad)
    with pytest.raises(guard.SortRuntimeError, match="len\\(stats\\)"):
        guard.check_bounds(plan, stats[:-1] if len(stats) > 1 else stats * 2)
    bad2 = [dict(st) for st in stats]
    bad2[0]["capacity"] = int(bad2[0]["capacity"]) + 128
    with pytest.raises(guard.SortRuntimeError, match="capacity"):
        guard.check_bounds(plan, bad2)


def test_check_full_detects_corruption(rng):
    x = jnp.asarray(rng.integers(0, 10**6, 500).astype(np.int32))
    plan = bucket_sort.resolve_plan(x.shape[0], x.dtype, CFG)
    codec = codec_for(x.dtype, False)
    kw = tuple(w[None, :] for w in codec.encode(x))
    vals = jnp.arange(500, dtype=jnp.int32)[None, :]
    order = jnp.argsort(x)[None, :]
    skw = tuple(jnp.take_along_axis(w, order, axis=1) for w in kw)
    sv = jnp.take_along_axis(vals, order, axis=1)
    guard.check_full(plan, kw, vals, skw, sv)  # healthy: no raise
    # dropped/duplicated payload
    with pytest.raises(guard.SortRuntimeError, match="payload permutation"):
        guard.check_full(plan, kw, vals, skw, sv.at[0, 0].set(sv[0, 1]))
    # corrupted key content
    bad_kw = tuple(w.at[0, 0].set(w[0, 0] + 1) for w in skw)
    with pytest.raises(guard.SortRuntimeError, match="key-word permutation"):
        guard.check_full(plan, kw, vals, bad_kw, sv)
    # unsorted output (swap, keeping the multiset intact)
    swap = jnp.asarray([499] + list(range(1, 499)) + [0])[None, :]
    ukw = tuple(jnp.take_along_axis(w, swap, axis=1) for w in skw)
    uv = jnp.take_along_axis(sv, swap, axis=1)
    with pytest.raises(guard.SortRuntimeError, match="sortedness"):
        guard.check_full(plan, kw, vals, ukw, uv)


def test_check_topk_detects_corruption(rng):
    x = jnp.asarray(rng.normal(size=200).astype(np.float32))
    codec = codec_for(x.dtype, descending=True)
    v, i = jax.lax.top_k(x, 5)
    i = i.astype(jnp.int32)
    guard.check_topk(x, v, i, 5, "full", codec)  # healthy
    with pytest.raises(guard.SortRuntimeError, match="idx"):
        guard.check_topk(x, v, i.at[0].set(999), 5, "bounds", codec)
    with pytest.raises(guard.SortRuntimeError, match="unique"):
        guard.check_topk(x, v, i.at[1].set(i[0]), 5, "full", codec)
    with pytest.raises(guard.SortRuntimeError, match="bitwise"):
        guard.check_topk(x, v.at[0].set(v[0] + 1), i, 5, "full", codec)
    with pytest.raises(guard.SortRuntimeError, match="descending"):
        guard.check_topk(x, v[::-1], i[::-1], 5, "full", codec)


# ----------------------------------------------------------------------
# Degradation machinery
# ----------------------------------------------------------------------


def test_with_retries_backoff_then_raise():
    calls, delays = [], []

    def flaky():
        calls.append(1)
        if len(calls) < 3:
            raise OSError("transient")
        return "ok"

    with warnings.catch_warnings():
        warnings.simplefilter("ignore", guard.DegradationWarning)
        assert guard.with_retries(
            flaky, site="autotune.measure", attempts=3,
            base_delay=0.01, sleep=delays.append) == "ok"
    assert len(calls) == 3
    assert delays == [0.01, 0.02]  # exponential
    log = guard.degradation_log()
    assert len(log) == 2 and all(ev.action == "retry" for ev in log)

    calls.clear()
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", guard.DegradationWarning)
        with pytest.raises(OSError):
            guard.with_retries(
                lambda: (_ for _ in ()).throw(OSError("always")),
                site="autotune.measure", attempts=2,
                base_delay=0.0, sleep=lambda _: None)


def test_degradation_log_bounded_and_clearable():
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", guard.DegradationWarning)
        for i in range(guard._LOG_MAX + 10):
            guard.record_degradation("s", "retry", "a", "b", f"e{i}")
    log = guard.degradation_log()
    assert len(log) == guard._LOG_MAX
    assert log[-1].error == f"e{guard._LOG_MAX + 9}"  # oldest evicted
    guard.clear_degradation_log()
    assert guard.degradation_log() == ()


def test_degradation_chain_on_kernel_fault(rng):
    """An injected kernel-launch fault must degrade, warn, and still
    return the bitwise-correct sorted output."""
    # fresh length => fresh plan => the trace actually runs (compiled
    # cache hits skip trace-time fault sites)
    x = jnp.asarray(rng.integers(0, 10**9, 3072).astype(np.int32))
    cfg = _cfg("full", tile=128, s=8, direct_max=256)
    with pytest.warns(guard.DegradationWarning):
        with faults.inject("kernel.launch", on_hit=1, count=10**6):
            out = bucket_sort.sort(x, cfg)
    np.testing.assert_array_equal(np.asarray(out), np.sort(np.asarray(x)))
    log = guard.degradation_log()
    assert any(ev.action == "fallback" for ev in log)


def test_store_quarantine_on_truncated_json(tmp_path, rng):
    """Satellite 1: a corrupt plan store must be QUARANTINED (atomic
    rename to plans.json.corrupt-<pid>), warned about once, and rebuilt
    — never crash, never silently overwrite the evidence."""
    path = str(tmp_path / "plans.json")
    with open(path, "w") as f:
        f.write('{"schema": 3, "plans": {"trunc')  # torn write
    autotune.clear_memo()
    with pytest.warns(guard.DegradationWarning, match="quarantin"):
        store = autotune._load_store(path)
    assert store["plans"] == {} and store["schema"] == autotune._STORE_SCHEMA
    corrupted = list(tmp_path.glob("plans.json.corrupt-*"))
    assert len(corrupted) == 1
    assert "trunc" in corrupted[0].read_text()  # evidence preserved
    assert not (tmp_path / "plans.json").exists()
    # the path is usable again: plan_for round-trips a fresh store
    plan = autotune.plan_for(
        2048, jnp.int32, CFG, path=path, max_trials=2, repeats=1,
        measure_budget=1)
    x = jnp.asarray(rng.integers(0, 10**6, 2048).astype(np.int32))
    np.testing.assert_array_equal(
        np.asarray(bucket_sort.sort_planned(x, plan)),
        np.sort(np.asarray(x)))
    assert json.load(open(path))["schema"] == autotune._STORE_SCHEMA
