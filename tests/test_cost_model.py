"""Analytic cost model + budgeted autotune (ISSUE 9 / DESIGN.md §10).

Covers:
  * rank correlation: cost-model scores vs measured wall time over a
    FIXED 12-candidate slice of distinct plan geometries at n=2^18
    (Spearman >= 0.6; the model needs to RANK, not predict micros);
  * properties (hypothesis when installed, seeded fallback otherwise):
    ``estimate`` is deterministic, strictly positive, and monotone in n
    at power-of-two doublings for fixed config;
  * the ``measure_budget`` knob: ValueError validation naming the
    field, base config always measured, deterministic tie-break on
    equal predicted cost (lower candidate index);
  * persistence: records carry the cost-model version, a stale version
    at load is a clean miss that re-tunes, and cross-shape transfer
    at a new length converges with <= 2 measurements.
"""

from __future__ import annotations

import dataclasses
import json

import numpy as np
import pytest

from repro.core import autotune as autotune_mod
from repro.core import cost_model, probe
from repro.core.plan import build_plan, build_shard_plan, build_topk_plan
from repro.core.sort_config import SortConfig

_XLA = SortConfig(tile=256, s=16, direct_max=512, impl="xla")
_BASE = SortConfig(tile=4096, s=64, direct_max=8192, impl="xla")

# The fixed 12-candidate slice: distinct plan GEOMETRIES (block_rows
# variants are identical plans on xla and would only measure timer
# noise), spanning the strategy, tile, s, fusion and relocation axes.
SLICE = (
    ("base", {}),
    ("radix", dict(strategy="radix")),
    ("merge", dict(strategy="merge")),
    ("tile=2048", dict(tile=2048)),
    ("tile=16384", dict(tile=16384, direct_max=32768)),
    ("tile=1024", dict(tile=1024)),
    ("s=32", dict(s=32)),
    ("s=128", dict(s=128)),
    ("s=256", dict(s=256)),
    ("scatter", dict(relocation="scatter")),
    ("nofuse", dict(fuse_sampling=False, fuse_ranking=False)),
    ("t8192s128", dict(tile=8192, s=128)),
)


def _spearman(a, b) -> float:
    a, b = np.asarray(a, float), np.asarray(b, float)
    n = len(a)

    def _ranks(v):
        r = np.empty(n)
        r[np.argsort(v, kind="stable")] = np.arange(n)
        return r

    ra, rb = _ranks(a), _ranks(b)
    return float(1.0 - 6.0 * np.sum((ra - rb) ** 2) / (n * (n * n - 1)))


def test_cost_model_ranks_fixed_slice_like_measurements():
    """The acceptance property of the whole tentpole: analytic scores
    order candidates like real wall time does, so pruning by predicted
    cost keeps the true winner in the measured set."""
    from repro.core import bucket_sort

    n = 1 << 18
    x = autotune_mod._sample_input(n, "int32", 1, 0)
    pred, meas = [], []
    for _, kw in SLICE:
        plan = build_plan(n, "int32", dataclasses.replace(_BASE, **kw))
        pred.append(cost_model.estimate(plan).total)
        meas.append(autotune_mod._measure(
            lambda a, p=plan: bucket_sort.sort_planned(a, p), x, repeats=2,
        ))
    rho = _spearman(pred, meas)
    assert rho >= 0.6, (rho, list(zip([l for l, _ in SLICE], pred, meas)))
    # The measured winner must survive a budget-5 cut of this slice.
    order = sorted(range(len(SLICE)), key=lambda i: (pred[i], i))
    assert int(np.argmin(meas)) in set(order[:5]) | {0}


# ----------------------------------------------------------------------
# estimate() properties
# ----------------------------------------------------------------------


def _assert_estimate_properties(log2n: int, kw: dict):
    cfg = dataclasses.replace(_BASE, **kw)
    p1 = build_plan(1 << log2n, "int32", cfg)
    p2 = build_plan(1 << log2n, "int32", cfg)
    a, b = cost_model.estimate(p1), cost_model.estimate(p2)
    assert a == b  # deterministic (and plan-equality stable)
    assert a.total > 0 and a.hbm_bytes > 0
    assert a.op_units >= 0 and a.collective_bytes >= 0
    assert a.align_penalty >= 1.0
    bigger = cost_model.estimate(build_plan(1 << (log2n + 1), "int32", cfg))
    assert bigger.total > a.total  # monotone at doublings


_KW_POOL = (
    {}, dict(strategy="radix"), dict(strategy="merge"), dict(tile=1024),
    dict(s=16), dict(relocation="scatter"), dict(fuse_sampling=False,
                                                 fuse_ranking=False),
)

try:  # optional dev dep (pip install -e '.[test]')
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=25, deadline=None)
    @given(st.integers(min_value=10, max_value=20),
           st.sampled_from(_KW_POOL))
    def test_estimate_deterministic_positive_monotone(log2n, kw):
        _assert_estimate_properties(log2n, kw)

except ModuleNotFoundError:  # seeded fallback keeps the invariant tested
    @pytest.mark.parametrize("seed", range(10))
    def test_estimate_deterministic_positive_monotone(seed):
        r = np.random.default_rng(seed)
        _assert_estimate_properties(
            int(r.integers(10, 21)), _KW_POOL[seed % len(_KW_POOL)]
        )


def test_estimate_covers_every_plan_type():
    sp = cost_model.estimate(build_plan(10_000, "int32", _XLA))
    tp = cost_model.estimate(build_topk_plan(10_000, 64, "float32", _XLA))
    hp = cost_model.estimate(build_shard_plan(("data",), 4, 4096, "int32",
                                              _XLA))
    assert sp.total > 0 and tp.total > 0 and hp.total > 0
    assert hp.collective_bytes > 0  # c_pair-padded exchange volume
    assert tp.total < sp.total  # partial sort moves less data
    with pytest.raises(TypeError):
        cost_model.estimate(object())
    d = sp.as_dict()
    assert d["total"] == sp.total and "hbm_bytes" in d


def test_priors_feed_strategy_dependent_terms():
    n = 1 << 18
    merge_plan = build_plan(
        n, "int32", dataclasses.replace(_BASE, strategy="merge")
    )
    uni = cost_model.estimate(merge_plan).total
    srt = cost_model.estimate(
        merge_plan, priors=cost_model.Priors(sortedness=1.0)
    ).total
    assert srt < uni  # sorted prior discounts merge compare work
    pri = probe.priors_for(np.arange(4096, dtype=np.int32))
    assert pri.sortedness == 1.0
    assert isinstance(pri, cost_model.Priors)


# ----------------------------------------------------------------------
# measure_budget semantics
# ----------------------------------------------------------------------


@pytest.mark.parametrize("bad", [0, -3, 1.5, "five", True])
def test_measure_budget_validation_names_the_field(bad):
    with pytest.raises(ValueError, match="measure_budget"):
        autotune_mod.autotune(4096, "int32", _XLA, measure_budget=bad)
    with pytest.raises(ValueError, match="measure_budget"):
        autotune_mod.autotune_shard(None, "data", 4096, "int32", _XLA,
                                    measure_budget=bad)


def test_select_measured_tie_break_is_candidate_index():
    pred = [3.0, 1.0, 1.0, 1.0, 2.0]
    got = autotune_mod._select_measured(pred, 3, [0])
    assert got == [0, 1, 2]  # equal predicted -> lower index wins
    assert got == autotune_mod._select_measured(pred, 3, [0])
    # mandatory indices survive even when predicted-expensive
    assert autotune_mod._select_measured(pred, 2, [0, 4]) == [0, 4]
    # None = exhaustive
    assert autotune_mod._select_measured(pred, None, [0]) == [0, 1, 2, 3, 4]


def test_base_config_always_measured_even_at_budget_one():
    res = autotune_mod.autotune(20_000, "int32", _XLA, max_trials=6,
                                repeats=1, measure_budget=1)
    measured = [c for c in res.candidates if c.us_per_call is not None]
    assert [c.index for c in measured] == [0]
    assert res.trials[0].label == "base"
    assert res.best_label == "base"
    assert res.measure_budget == 1
    assert len(res.candidates) == len(
        autotune_mod.candidate_space(_XLA, 20_000, max_trials=6)
    )


def test_budgeted_result_records_predicted_for_every_candidate():
    res = autotune_mod.autotune(20_000, "int32", _XLA, max_trials=6,
                                repeats=1, measure_budget=3)
    assert all(np.isfinite(c.predicted) for c in res.candidates)
    assert sum(1 for c in res.candidates if c.us_per_call is not None) == 3
    assert res.cost_model_version == cost_model.COST_MODEL_VERSION
    # unmeasured candidates are pruned, not silently dropped
    assert len(res.candidates) > 3


# ----------------------------------------------------------------------
# persistence: version stamping, stale-version re-tune, transfer
# ----------------------------------------------------------------------


def _counting_measure(monkeypatch):
    calls = []
    orig = autotune_mod._measure

    def _m(fn, x, **kw):
        calls.append(1)
        return orig(fn, x, **kw)

    monkeypatch.setattr(autotune_mod, "_measure", _m)
    return calls


def test_store_record_carries_cost_model_version(tmp_path):
    path = str(tmp_path / "plans.json")
    autotune_mod.clear_memo()
    autotune_mod.plan_for(20_000, "int32", _XLA, path=path, max_trials=4,
                          repeats=1)
    store = json.load(open(path))
    (rec,) = store["plans"].values()
    assert rec["cost_model"] == cost_model.COST_MODEL_VERSION
    assert rec["measured"] <= rec["candidates"]
    autotune_mod.clear_memo()


def test_stale_cost_model_version_is_a_clean_miss(tmp_path, monkeypatch):
    path = str(tmp_path / "plans.json")
    autotune_mod.clear_memo()
    autotune_mod.plan_for(20_000, "int32", _XLA, path=path, max_trials=4,
                          repeats=1)
    store = json.load(open(path))
    (key,) = store["plans"]
    store["plans"][key]["cost_model"] = "cost_model/v0"
    with open(path, "w") as f:
        json.dump(store, f)
    autotune_mod.clear_memo()
    calls = _counting_measure(monkeypatch)
    plan = autotune_mod.plan_for(20_000, "int32", _XLA, path=path,
                                 max_trials=4, repeats=1)
    assert calls  # re-tuned instead of trusting the stale record
    store = json.load(open(path))
    assert store["plans"][key]["cost_model"] == cost_model.COST_MODEL_VERSION
    assert plan is not None
    autotune_mod.clear_memo()


def test_transfer_converges_within_two_measurements(tmp_path, monkeypatch):
    path = str(tmp_path / "plans.json")
    autotune_mod.clear_memo()
    autotune_mod.plan_for(20_000, "int32", _XLA, path=path, max_trials=6,
                          repeats=1)
    calls = _counting_measure(monkeypatch)
    plan2 = autotune_mod.plan_for(40_000, "int32", _XLA, path=path,
                                  max_trials=6, repeats=1)
    assert len(calls) <= 2
    assert plan2.length == 40_000
    store = json.load(open(path))
    rec2 = next(v for k, v in store["plans"].items() if "40000" in k)
    assert rec2["transfer_from"].split("|")[1] == "20000"
    assert rec2["measured"] <= 2
    autotune_mod.clear_memo()


def test_transfer_disabled_or_exhaustive_measures_fully(tmp_path,
                                                        monkeypatch):
    path = str(tmp_path / "plans.json")
    autotune_mod.clear_memo()
    autotune_mod.plan_for(20_000, "int32", _XLA, path=path, max_trials=4,
                          repeats=1)
    calls = _counting_measure(monkeypatch)
    autotune_mod.plan_for(40_000, "int32", _XLA, path=path, max_trials=4,
                          repeats=1, transfer=False, measure_budget=None)
    space = autotune_mod.candidate_space(_XLA, 40_000, max_trials=4)
    assert len(calls) == len(space)
    autotune_mod.clear_memo()
