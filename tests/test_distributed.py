"""Distributed tests run in SUBPROCESSES with forced host device counts
(the main pytest process must keep the real 1-CPU topology)."""

import os
import subprocess
import sys
import textwrap

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_sub(code: str, devices: int = 8, timeout: int = 420):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    r = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=timeout, env=env,
    )
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-4000:]}"
    return r.stdout


def test_distributed_sort_8dev():
    run_sub("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.core.distributed_sort import make_sharded_sort
        from repro.core.sort_config import SortConfig
        from repro.launch.mesh import make_mesh
        mesh = make_mesh((4, 2), ("data", "model"))
        cfg = SortConfig(tile=256, s=16, direct_max=512, impl="xla")
        rng = np.random.default_rng(3)
        for n, axis in [(8192, "data"), (8192, ("data", "model"))]:
            run, spec = make_sharded_sort(mesh, axis, n, cfg, oversample=8)
            for dist in ["uniform", "equal", "skew"]:
                if dist == "uniform": x = rng.integers(-2**31, 2**31-1, n).astype(np.int32)
                elif dist == "equal": x = np.full(n, -3, np.int32)
                else: x = (rng.zipf(1.5, n) % 100000).astype(np.int32)
                sk, sv, counts, mw = map(np.asarray, run(jnp.asarray(x)))
                oc = spec.out_cap
                got = np.concatenate([sk[i*oc:i*oc+counts[i]] for i in range(spec.d)])
                assert (got == np.sort(x)).all(), (n, axis, dist)
                assert (mw < spec.c_pair).all()
                pv = np.concatenate([sv[i*oc:i*oc+counts[i]] for i in range(spec.d)])
                assert (x[pv] == got).all()
        print("OK")
    """)


# ----------------------------------------------------------------------
# Differential-conformance slice (ISSUE 8): mesh D in {2, 4, 8} (incl.
# a 2-axis sort) x 5 dtypes x asc/desc x 4 distributions, every cell
# checked against the np.sort / argsort-permutation oracles and the
# max_within < c_pair capacity invariant.  Zero xfails.
# ----------------------------------------------------------------------

_CONFORMANCE = """
    import jax
    jax.config.update("jax_enable_x64", True)  # int64/float64 codecs
    import numpy as np, jax.numpy as jnp
    from repro.core.distributed_sort import make_sharded_sort
    from repro.core.sort_config import SortConfig
    from repro.launch.mesh import make_mesh

    mesh = make_mesh({shape}, {names})
    axis = {axis}
    n = 4096
    rng = np.random.default_rng(42)

    def gen(dtype, dist):
        if np.issubdtype(np.dtype(dtype), np.floating):
            base = (rng.standard_normal(n) * 1e6).astype(dtype)
        else:
            info = np.iinfo(dtype)
            base = rng.integers(
                info.min, info.max, n, dtype=np.int64).astype(dtype)
        if dist == "uniform":
            return base
        if dist == "equal":
            return np.full(n, base[0], dtype)
        if dist == "zipf":
            return (rng.zipf(1.5, n) % 100000).astype(dtype)
        if dist == "nearly-sorted":
            x = np.sort(base)
            idx = rng.integers(0, n - 1, n // 100)
            x[idx], x[idx + 1] = x[idx + 1].copy(), x[idx].copy()
            return x
        raise KeyError(dist)

    cells = 0
    for dtype in ["int32", "uint32", "int64", "float32", "float64"]:
        for desc in [False, True]:
            cfg = SortConfig(tile=256, s=16, direct_max=512, impl="xla",
                             descending=desc)
            run, plan = make_sharded_sort(
                mesh, axis, n, cfg, dtype=jnp.dtype(dtype))
            for dist in ["uniform", "equal", "zipf", "nearly-sorted"]:
                x = gen(dtype, dist)
                sk, sv, counts, mw = map(np.asarray, run(jnp.asarray(x)))
                oc = plan.out_cap
                got = np.concatenate(
                    [sk[i*oc:i*oc+counts[i]] for i in range(plan.d)])
                ref = np.sort(x)[::-1] if desc else np.sort(x)
                cell = (dtype, desc, dist)
                assert counts.sum() == n, (cell, counts)
                np.testing.assert_array_equal(got, ref, err_msg=str(cell))
                pv = np.concatenate(
                    [sv[i*oc:i*oc+counts[i]] for i in range(plan.d)])
                assert sorted(pv) == list(range(n)), (cell, "not a perm")
                np.testing.assert_array_equal(x[pv], got, err_msg=str(cell))
                assert (mw < plan.c_pair).all(), (cell, mw, plan.c_pair)
                cells += 1
    print("OK", cells, "cells")
"""


@pytest.mark.parametrize("devices,shape,names,axis", [
    (2, (2,), ("data",), "data"),
    (4, (4,), ("data",), "data"),
    (8, (4, 2), ("data", "model"), ("data", "model")),
], ids=["d2", "d4", "d8-2axis"])
def test_distributed_conformance_matrix(devices, shape, names, axis):
    out = run_sub(
        _CONFORMANCE.format(shape=shape, names=names, axis=repr(axis)),
        devices=devices, timeout=600,
    )
    assert "OK 40 cells" in out


def test_shard_trace_discipline_4dev():
    """Same (mesh, n, dtype, cfg) -> ONE trace shared across fresh
    make_sharded_sort calls; distinct oversample -> distinct
    executable."""
    run_sub("""
        import numpy as np, jax.numpy as jnp
        from repro.core.distributed_sort import make_sharded_sort, trace_count
        from repro.core.sort_config import SortConfig
        from repro.launch.mesh import make_mesh
        mesh = make_mesh((4,), ("data",))
        cfg = SortConfig(tile=256, s=16, direct_max=512, impl="xla")
        x = jnp.asarray(np.random.default_rng(0).integers(
            0, 1000, 4096).astype(np.int32))
        run1, p1 = make_sharded_sort(mesh, "data", 4096, cfg)
        t0 = trace_count()
        run1(x)
        assert trace_count() == t0 + 1, "first call must trace exactly once"
        run1(x)
        assert trace_count() == t0 + 1, "same-signature call retraced"
        run2, p2 = make_sharded_sort(mesh, "data", 4096, cfg)
        assert p2 is p1, "equal signature must return the memoized plan"
        run2(x)
        assert trace_count() == t0 + 1, "fresh equal-signature fn retraced"
        run3, p3 = make_sharded_sort(mesh, "data", 4096, cfg, oversample=4)
        assert p3 != p1 and p3.signature() != p1.signature()
        run3(x)
        assert trace_count() == t0 + 2, "distinct oversample must retrace"
        print("OK")
    """, devices=4)


def test_shard_plan_cache_hit_zero_retrace_2dev(tmp_path):
    """plan='autotune': first resolve tunes and persists; after
    clear_memo() the disk record reloads an EQUAL plan, so the jit
    static-arg cache hits -> zero retraces."""
    run_sub(f"""
        import os
        os.environ["REPRO_SORT_PLAN_CACHE"] = {str(tmp_path / "p.json")!r}
        import numpy as np, jax.numpy as jnp
        from repro.core import autotune
        from repro.core.distributed_sort import make_sharded_sort, trace_count
        from repro.core.sort_config import SortConfig
        from repro.launch.mesh import make_mesh
        mesh = make_mesh((2,), ("data",))
        cfg = SortConfig(tile=256, s=16, direct_max=512, impl="xla",
                         plan="autotune")
        x = jnp.asarray(np.random.default_rng(1).integers(
            0, 10**6, 2048).astype(np.int32))
        run1, p1 = make_sharded_sort(mesh, "data", 2048, cfg)
        run1(x)
        autotune.clear_memo()  # force the on-disk path
        t0 = trace_count()
        run2, p2 = make_sharded_sort(mesh, "data", 2048, cfg)
        assert p2 == p1, "reloaded shard plan differs from the tuned one"
        run2(x)
        assert trace_count() == t0, "shard-plan-cache hit retraced"
        # persisted under the BASE signature's key (the lookup identity;
        # the tuned winner itself may carry a different cfg/knobs)
        import json
        store = json.load(open(os.environ["REPRO_SORT_PLAN_CACHE"]))
        keys = [k for k in store["plans"] if k.startswith("shard|")]
        assert keys, "tuned shard plan not persisted"
        print("OK")
    """, devices=2)


def test_make_sharded_sort_validation_messages_2dev():
    """The bare asserts became field-naming ValueErrors (ISSUE 8):
    n_global divisibility, the int32 payload budget, plan-build-time
    oversample validation, and the runtime dtype check."""
    run_sub("""
        import numpy as np, jax.numpy as jnp
        from repro.core.distributed_sort import make_sharded_sort
        from repro.core.sort_config import SortConfig
        from repro.launch.mesh import make_mesh
        mesh = make_mesh((2,), ("data",))
        cfg = SortConfig(tile=256, s=16, direct_max=512, impl="xla")
        def expect(msg, fn):
            try:
                fn()
            except ValueError as e:
                assert msg in str(e), (msg, str(e))
            else:
                raise AssertionError(f"no ValueError: {msg}")
        expect("must be divisible by the axis device count",
               lambda: make_sharded_sort(mesh, "data", 1001, cfg))
        expect("exceeds the int32 payload budget",
               lambda: make_sharded_sort(mesh, "data", 2**27, cfg))
        expect("oversample must be a power of two",
               lambda: make_sharded_sort(mesh, "data", 2048, cfg, 5))
        expect("pair_align must be a power of two >= 8",
               lambda: make_sharded_sort(mesh, "data", 2048, cfg,
                                         pair_align=4))
        run, plan = make_sharded_sort(mesh, "data", 2048, cfg)
        expect("does not match the shard plan's dtype",
               lambda: run(jnp.zeros(2048, jnp.float32)))
        print("OK")
    """, devices=2)


def test_make_sharded_sort_rejects_single_device_axis():
    """d < 2 raises in-process (no forced-host mesh needed)."""
    from repro.core.distributed_sort import make_sharded_sort
    from repro.core.sort_config import SortConfig
    from repro.launch.mesh import make_mesh

    mesh = make_mesh((1,), ("data",))
    with pytest.raises(ValueError, match=r"need d >= 2"):
        make_sharded_sort(
            mesh, "data", 1024,
            SortConfig(tile=256, s=16, direct_max=512, impl="xla"),
        )


def test_sharded_train_step_8dev():
    """GSPMD train step on a 4x2 mesh: loss decreases, params sharded."""
    run_sub("""
        import numpy as np, jax, jax.numpy as jnp
        from repro import configs, sharding as shd
        from repro.config import OptimizerConfig, ParallelConfig, ShapeConfig
        from repro.launch.mesh import make_mesh
        from repro.launch.steps import build_train_step, make_plan, param_shardings
        from repro.models import api, meta
        from repro.optim import adamw_init
        import dataclasses

        model = configs.get_smoke("qwen3-moe-30b-a3b")
        model = dataclasses.replace(model, vocab=512)
        arch = dataclasses.replace(configs.get_config("qwen3-moe-30b-a3b"), model=model)
        par = ParallelConfig(mesh_shape=(4, 2), mesh_axes=("data", "model"))
        mesh = make_mesh((4, 2), ("data", "model"))
        shp = ShapeConfig("t", 64, 8, "train")
        plan = make_plan(arch, shp, mesh, par)
        opt = OptimizerConfig(lr=1e-3, warmup_steps=2, total_steps=20)
        tpl = api.template(model)
        with shd.sharding_ctx(mesh, plan.rules):
            params = meta.init_params(tpl, jax.random.PRNGKey(0))
            params = jax.tree.map(jax.device_put, params, param_shardings(plan))
            state = adamw_init(params, opt)
            step = jax.jit(build_train_step(plan, opt), donate_argnums=(0, 1))
            rng = np.random.default_rng(0)
            toks = rng.integers(0, 512, (8, 65)).astype(np.int32)
            batch = {"tokens": jnp.asarray(toks[:, :-1]), "targets": jnp.asarray(toks[:, 1:])}
            losses = []
            for i in range(12):  # overfit one fixed batch -> must decrease
                params, state, m = step(params, state, batch)
                losses.append(float(m["loss"]))
        assert all(np.isfinite(losses)), losses
        assert losses[-1] < losses[0] - 0.1, losses
        print("OK", losses[0], "->", losses[-1])
    """)


def test_multipod_mini_dryrun():
    """Mini multi-pod proof: (2,2,2) pod/data/model mesh lowers+compiles
    a train step AND a decode step for a reduced hybrid config."""
    run_sub("""
        import dataclasses, jax
        from repro import configs
        from repro.config import ParallelConfig, ShapeConfig
        from repro.launch.mesh import make_mesh
        from repro.launch.steps import lower_cell, make_plan

        model = configs.get_smoke("jamba-1.5-large-398b")
        arch = dataclasses.replace(
            configs.get_config("jamba-1.5-large-398b"), model=model, fsdp=True)
        par = ParallelConfig(mesh_shape=(2, 2, 2),
                             mesh_axes=("pod", "data", "model"), fsdp=True)
        mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
        for shp in [ShapeConfig("t", 64, 8, "train"), ShapeConfig("d", 64, 8, "decode")]:
            plan = make_plan(arch, shp, mesh, par)
            lowered, kind = lower_cell(plan)
            compiled = lowered.compile()
            assert compiled is not None
            print(kind, "compiled OK")
    """)


def test_compressed_allreduce_8dev():
    """int8 gradient all-reduce with error feedback ~ fp32 psum mean."""
    run_sub("""
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from repro.optim.compress import allreduce_compressed
        from repro.launch.mesh import make_mesh
        mesh = make_mesh((8,), ("data",))
        def body(g):
            mean, res = allreduce_compressed({"w": g}, "data")
            exact = jax.lax.pmean(g, "data")
            return mean["w"][None], res["w"][None], exact[None]
        from repro.compat import shard_map
        f = jax.jit(shard_map(body, mesh=mesh, in_specs=(P("data"),),
                    out_specs=(P("data"), P("data"), P("data"))))
        rng = np.random.default_rng(0)
        g = jnp.asarray(rng.normal(size=(8, 1024)).astype(np.float32))
        mean, res, exact = f(g.reshape(8*1024))
        err = np.abs(np.asarray(mean) - np.asarray(exact)).max()
        scale = np.abs(np.asarray(exact)).max()
        assert err < 0.05 * scale + 0.05, (err, scale)
        # error feedback residual bounded by one quantization step
        assert np.abs(np.asarray(res)).max() <= np.abs(np.asarray(g)).max() / 127 + 1e-6
        print("OK", err)
    """)
