"""Distributed tests run in SUBPROCESSES with forced host device counts
(the main pytest process must keep the real 1-CPU topology)."""

import os
import subprocess
import sys
import textwrap

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_sub(code: str, devices: int = 8, timeout: int = 420):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    r = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=timeout, env=env,
    )
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-4000:]}"
    return r.stdout


def test_distributed_sort_8dev():
    run_sub("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.core.distributed_sort import make_sharded_sort
        from repro.core.sort_config import SortConfig
        from repro.launch.mesh import make_mesh
        mesh = make_mesh((4, 2), ("data", "model"))
        cfg = SortConfig(tile=256, s=16, direct_max=512, impl="xla")
        rng = np.random.default_rng(3)
        for n, axis in [(8192, "data"), (8192, ("data", "model"))]:
            run, spec = make_sharded_sort(mesh, axis, n, cfg, oversample=8)
            for dist in ["uniform", "equal", "skew"]:
                if dist == "uniform": x = rng.integers(-2**31, 2**31-1, n).astype(np.int32)
                elif dist == "equal": x = np.full(n, -3, np.int32)
                else: x = (rng.zipf(1.5, n) % 100000).astype(np.int32)
                sk, sv, counts, mw = map(np.asarray, run(jnp.asarray(x)))
                oc = spec.out_cap
                got = np.concatenate([sk[i*oc:i*oc+counts[i]] for i in range(spec.d)])
                assert (got == np.sort(x)).all(), (n, axis, dist)
                assert (mw < spec.c_pair).all()
                pv = np.concatenate([sv[i*oc:i*oc+counts[i]] for i in range(spec.d)])
                assert (x[pv] == got).all()
        print("OK")
    """)


def test_sharded_train_step_8dev():
    """GSPMD train step on a 4x2 mesh: loss decreases, params sharded."""
    run_sub("""
        import numpy as np, jax, jax.numpy as jnp
        from repro import configs, sharding as shd
        from repro.config import OptimizerConfig, ParallelConfig, ShapeConfig
        from repro.launch.mesh import make_mesh
        from repro.launch.steps import build_train_step, make_plan, param_shardings
        from repro.models import api, meta
        from repro.optim import adamw_init
        import dataclasses

        model = configs.get_smoke("qwen3-moe-30b-a3b")
        model = dataclasses.replace(model, vocab=512)
        arch = dataclasses.replace(configs.get_config("qwen3-moe-30b-a3b"), model=model)
        par = ParallelConfig(mesh_shape=(4, 2), mesh_axes=("data", "model"))
        mesh = make_mesh((4, 2), ("data", "model"))
        shp = ShapeConfig("t", 64, 8, "train")
        plan = make_plan(arch, shp, mesh, par)
        opt = OptimizerConfig(lr=1e-3, warmup_steps=2, total_steps=20)
        tpl = api.template(model)
        with shd.sharding_ctx(mesh, plan.rules):
            params = meta.init_params(tpl, jax.random.PRNGKey(0))
            params = jax.tree.map(jax.device_put, params, param_shardings(plan))
            state = adamw_init(params, opt)
            step = jax.jit(build_train_step(plan, opt), donate_argnums=(0, 1))
            rng = np.random.default_rng(0)
            toks = rng.integers(0, 512, (8, 65)).astype(np.int32)
            batch = {"tokens": jnp.asarray(toks[:, :-1]), "targets": jnp.asarray(toks[:, 1:])}
            losses = []
            for i in range(12):  # overfit one fixed batch -> must decrease
                params, state, m = step(params, state, batch)
                losses.append(float(m["loss"]))
        assert all(np.isfinite(losses)), losses
        assert losses[-1] < losses[0] - 0.1, losses
        print("OK", losses[0], "->", losses[-1])
    """)


def test_multipod_mini_dryrun():
    """Mini multi-pod proof: (2,2,2) pod/data/model mesh lowers+compiles
    a train step AND a decode step for a reduced hybrid config."""
    run_sub("""
        import dataclasses, jax
        from repro import configs
        from repro.config import ParallelConfig, ShapeConfig
        from repro.launch.mesh import make_mesh
        from repro.launch.steps import lower_cell, make_plan

        model = configs.get_smoke("jamba-1.5-large-398b")
        arch = dataclasses.replace(
            configs.get_config("jamba-1.5-large-398b"), model=model, fsdp=True)
        par = ParallelConfig(mesh_shape=(2, 2, 2),
                             mesh_axes=("pod", "data", "model"), fsdp=True)
        mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
        for shp in [ShapeConfig("t", 64, 8, "train"), ShapeConfig("d", 64, 8, "decode")]:
            plan = make_plan(arch, shp, mesh, par)
            lowered, kind = lower_cell(plan)
            compiled = lowered.compile()
            assert compiled is not None
            print(kind, "compiled OK")
    """)


def test_compressed_allreduce_8dev():
    """int8 gradient all-reduce with error feedback ~ fp32 psum mean."""
    run_sub("""
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from repro.optim.compress import allreduce_compressed
        from repro.launch.mesh import make_mesh
        mesh = make_mesh((8,), ("data",))
        def body(g):
            mean, res = allreduce_compressed({"w": g}, "data")
            exact = jax.lax.pmean(g, "data")
            return mean["w"][None], res["w"][None], exact[None]
        from repro.compat import shard_map
        f = jax.jit(shard_map(body, mesh=mesh, in_specs=(P("data"),),
                    out_specs=(P("data"), P("data"), P("data"))))
        rng = np.random.default_rng(0)
        g = jnp.asarray(rng.normal(size=(8, 1024)).astype(np.float32))
        mean, res, exact = f(g.reshape(8*1024))
        err = np.abs(np.asarray(mean) - np.asarray(exact)).max()
        scale = np.abs(np.asarray(exact)).max()
        assert err < 0.05 * scale + 0.05, (err, scale)
        # error feedback residual bounded by one quantization step
        assert np.abs(np.asarray(res)).max() <= np.abs(np.asarray(g)).max() / 127 + 1e-6
        print("OK", err)
    """)
