"""Hybrid local-sort strategy dispatch (ISSUE 6 / DESIGN.md §8).

Covers:
  * conformance: strategy (bitonic/radix/merge) x dtype (int32 / uint32
    / int64 / float32) x impl (xla, interpreted Pallas) against the
    numpy stable oracles — values AND permutations;
  * hypothesis properties: the radix and merge pipelines are
    permutation- and stability-EQUAL to the bitonic pipeline (same
    plan geometry, only ``strategy`` differs);
  * planner: candidate 0 of the autotune space is still the base
    config; the fingerprint extends over the new fields; a stale
    pre-strategy ``sort_plan/v1`` cache record triggers a clean
    re-tune instead of a misread;
  * zero new retraces: equal strategy plans share one executable;
  * ``SortConfig.__post_init__`` names the offending field;
  * the distribution probe's recommendations and its tracer rejection.
"""

import contextlib
import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import autotune as autotune_mod
from repro.core import bucket_sort, probe
from repro.core.autotune import cache_key
from repro.core.plan import build_plan, config_fingerprint, plan_to_dict
from repro.core.sort_config import DEFAULT_CONFIG, SortConfig

STRATEGIES = ("bitonic", "radix", "merge")

_XLA = SortConfig(tile=256, s=16, direct_max=512, impl="xla")
_PAL = SortConfig(tile=128, s=8, direct_max=256, impl="pallas", interpret=True)

CELLS = [pytest.param(_XLA, id="xla"), pytest.param(_PAL, id="pallas-interpret")]

DTYPES = ["int32", "uint32", "int64", "float32"]


def dtype_ctx(dtype):
    if dtype == "int64":
        return jax.experimental.enable_x64()
    return contextlib.nullcontext()


def make_keys(dtype, n, rng):
    if dtype == "int32":
        return rng.integers(-(2**31), 2**31 - 1, n).astype(np.int32)
    if dtype == "uint32":
        return rng.integers(0, 2**32, n, dtype=np.uint64).astype(np.uint32)
    if dtype == "int64":
        return rng.integers(-(2**63), 2**63 - 1, n, dtype=np.int64)
    if dtype == "float32":
        x = rng.normal(0, 1e9, n).astype(np.float32)
        x[rng.integers(0, n, max(n // 64, 1))] = np.inf
        x[rng.integers(0, n, max(n // 64, 1))] = -np.inf
        return x
    raise KeyError(dtype)


# ----------------------------------------------------------------------
# Conformance: strategy x dtype x impl
# ----------------------------------------------------------------------


@pytest.mark.parametrize("cfg0", CELLS)
@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("strategy", STRATEGIES)
def test_strategy_conformance(cfg0, dtype, strategy, rng):
    cfg = dataclasses.replace(cfg0, strategy=strategy)
    # The small size stays on the direct path; the large one crosses the
    # cell's direct_max into a bucket round — both paths run every
    # strategy.  Interpret-mode Pallas runs the radix/merge inner loops
    # in pure Python, so that cell uses smaller sizes to stay fast.
    sizes = (127, 1500) if cfg.impl == "xla" else (63, 300)
    for n in sizes:
        with dtype_ctx(dtype):
            x = make_keys(dtype, n, rng)
            out = np.asarray(bucket_sort.sort(jnp.asarray(x), cfg))
            np.testing.assert_array_equal(out, np.sort(x))
            perm = np.asarray(bucket_sort.argsort(jnp.asarray(x), cfg))
            np.testing.assert_array_equal(perm, np.argsort(x, kind="stable"))


@pytest.mark.parametrize("strategy", ["radix", "merge"])
def test_strategy_kv_and_batched(strategy, rng):
    cfg = dataclasses.replace(_XLA, strategy=strategy)
    x = rng.integers(0, 50, 1500).astype(np.int32)  # heavy duplicates
    v = np.arange(1500, dtype=np.int32)
    k2, v2 = bucket_sort.sort_kv(jnp.asarray(x), jnp.asarray(v), cfg)
    perm = np.argsort(x, kind="stable")
    np.testing.assert_array_equal(np.asarray(k2), x[perm])
    np.testing.assert_array_equal(np.asarray(v2), perm)
    xb = rng.integers(-1000, 1000, (5, 700)).astype(np.int32)
    outb = np.asarray(bucket_sort.sort_batched(jnp.asarray(xb), cfg))
    np.testing.assert_array_equal(outb, np.sort(xb, axis=-1))


# ----------------------------------------------------------------------
# Property: radix/merge pipelines equal the bitonic pipeline
# ----------------------------------------------------------------------

def _assert_pipelines_equal(xs):
    """With heavy duplicates, the three strategies must emit the SAME
    permutation (stability ties broken identically), not merely the
    same sorted values."""
    x = jnp.asarray(np.asarray(xs, np.int32))
    ref = np.asarray(
        bucket_sort.argsort(x, dataclasses.replace(_XLA, strategy="bitonic"))
    )
    np.testing.assert_array_equal(ref, np.argsort(np.asarray(x), kind="stable"))
    for strategy in ("radix", "merge"):
        got = np.asarray(
            bucket_sort.argsort(x, dataclasses.replace(_XLA, strategy=strategy))
        )
        np.testing.assert_array_equal(got, ref)


try:  # optional dev dep (pip install -e '.[test]')
    from hypothesis import given, settings, strategies as st

    small_ints = st.lists(
        st.integers(min_value=0, max_value=7), min_size=1, max_size=2000
    )

    @settings(max_examples=20, deadline=None)
    @given(small_ints)
    def test_strategy_pipelines_permutation_and_stability_equal(xs):
        _assert_pipelines_equal(xs)

except ModuleNotFoundError:  # seeded fallback keeps the invariant tested
    @pytest.mark.parametrize("seed", range(6))
    def test_strategy_pipelines_permutation_and_stability_equal(seed):
        r = np.random.default_rng(seed)
        n = int(r.integers(1, 2000))
        _assert_pipelines_equal(r.integers(0, 8, n).astype(np.int32))


# ----------------------------------------------------------------------
# Planner integration
# ----------------------------------------------------------------------


def test_strategy_candidate_space_keeps_base_first():
    cands = autotune_mod.candidate_space(_XLA, 100_000, max_trials=16)
    assert cands[0].cfg == _XLA and cands[0].label == "base"
    seen = {c.cfg.strategy for c in cands}
    assert seen == set(STRATEGIES), f"strategy axis missing: {seen}"


def test_strategy_extends_config_fingerprint():
    a = config_fingerprint(_XLA)
    assert config_fingerprint(dataclasses.replace(_XLA, strategy="radix")) != a
    assert config_fingerprint(dataclasses.replace(_XLA, radix_bits=2)) != a
    assert config_fingerprint(dataclasses.replace(_XLA, merge_run=128)) != a
    # plan= stays excluded (it selects a plan, it does not shape one)
    assert config_fingerprint(dataclasses.replace(_XLA, plan="autotune")) == a


def test_strategy_stale_v1_cache_record_retunes_cleanly(tmp_path):
    """A pre-strategy ``sort_plan/v1`` record in the plan store must be
    treated as a miss: plan_for re-tunes and overwrites, no crash."""
    cfg = dataclasses.replace(_XLA, plan="autotune")
    base = build_plan(2333, "int32", cfg)
    stale = plan_to_dict(base)
    stale["schema"] = "sort_plan/v1"
    path = tmp_path / "plans.json"
    path.write_text(json.dumps({
        "schema": "sort_plan_cache/v1",
        "plans": {cache_key(base): {"plan": stale, "best_us": 1.0}},
    }))
    autotune_mod.clear_memo()
    plan = autotune_mod.plan_for(
        2333, "int32", cfg, path=str(path), max_trials=3, repeats=1
    )
    assert plan.root.strategy in STRATEGIES
    fresh = json.loads(path.read_text())["plans"][cache_key(base)]
    assert fresh["plan"]["schema"] == "sort_plan/v2"


@pytest.mark.parametrize("strategy", ["radix", "merge"])
def test_strategy_same_signature_traces_once(strategy, rng):
    cfg = dataclasses.replace(_XLA, strategy=strategy)
    x = jnp.asarray(rng.integers(0, 10_000, 2048).astype(np.int32))
    bucket_sort.sort(x, cfg)  # may compile
    t0 = bucket_sort.trace_count()
    bucket_sort.sort(x, cfg)
    assert bucket_sort.trace_count() == t0, f"{strategy} plan retraced"


# ----------------------------------------------------------------------
# Config validation
# ----------------------------------------------------------------------


@pytest.mark.parametrize("kw, field", [
    (dict(strategy="quantum"), "strategy"),
    (dict(radix_bits=3), "radix_bits"),
    (dict(radix_bits=8), "radix_bits"),
    (dict(merge_run=100), "merge_run"),
])
def test_strategy_config_validation_names_field(kw, field):
    with pytest.raises(ValueError, match=field):
        dataclasses.replace(DEFAULT_CONFIG, **kw)


# ----------------------------------------------------------------------
# Distribution probe
# ----------------------------------------------------------------------


def test_strategy_probe_recommends_merge_on_sorted(rng):
    x = np.sort(rng.integers(-(2**31), 2**31 - 1, 1 << 20).astype(np.int32))
    stats = probe.probe(x)
    assert stats["sortedness"] >= probe.SORTEDNESS_MERGE_THRESHOLD
    assert probe.recommend_strategy(x) == "merge"
    assert probe.probed_config(x).strategy == "merge"


def test_strategy_probe_recommends_radix_on_large_uniform(rng):
    x = rng.integers(-(2**31), 2**31 - 1, 1 << 20).astype(np.int32)
    assert probe.recommend_strategy(x) == "radix"


def test_strategy_probe_falls_back_to_bitonic(rng):
    dup = np.full(1 << 20, 42, np.int32)  # zero entropy, unsorted? sorted!
    # all-equal IS sorted -> merge; use a low-entropy unsorted input:
    x = rng.choice(np.array([3, 7], np.int32), 1 << 20)
    assert probe.recommend_strategy(x) == "bitonic"
    small = rng.integers(-(2**31), 2**31 - 1, 1024).astype(np.int32)
    assert probe.recommend_strategy(small) == "bitonic"  # below RADIX_MIN_N
    assert probe.recommend_strategy(dup) == "merge"  # sorted beats entropy


def test_strategy_probe_rejects_tracers():
    @jax.jit
    def bad(x):
        return probe.recommend_strategy(x)

    with pytest.raises(TypeError, match="concrete"):
        bad(jnp.arange(100, dtype=jnp.int32))
