"""Per-architecture smoke tests (assignment deliverable f): a REDUCED
config of the same family runs one forward/train step on CPU with
correct output shapes and no NaNs, plus a prefill+decode step."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import api, meta


def _batch(cfg, rng, batch=2, seq=32):
    b = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (batch, seq)), jnp.int32),
        "targets": jnp.asarray(rng.integers(0, cfg.vocab, (batch, seq)), jnp.int32),
    }
    if api.is_encdec(cfg):
        b["enc_frames"] = jnp.asarray(
            rng.normal(size=(batch, cfg.encoder_positions, cfg.d_model)).astype(np.float32)
        ).astype(cfg.dtype)
    elif cfg.frontend != "none" and cfg.frontend_len:
        b["prefix_embeds"] = jnp.asarray(
            rng.normal(size=(batch, cfg.frontend_len, cfg.d_model)).astype(np.float32)
        ).astype(cfg.dtype)
    return b


@pytest.mark.parametrize("arch", configs.all_archs())
def test_arch_smoke_train_and_serve(arch, rng):
    cfg = configs.get_smoke(arch)
    tpl = api.template(cfg)
    params = meta.init_params(tpl, jax.random.PRNGKey(0))
    batch, seq, cache_len = 2, 32, 48
    bd = _batch(cfg, rng, batch, seq)

    # one train step: loss + grads finite
    loss, grads = jax.jit(
        jax.value_and_grad(lambda p, b: api.loss_fn(p, b, cfg))
    )(params, bd)
    assert np.isfinite(float(loss)), arch
    gnorm = sum(
        float(jnp.sum(jnp.abs(g.astype(jnp.float32)))) for g in jax.tree.leaves(grads)
    )
    assert np.isfinite(gnorm) and gnorm > 0, arch

    # serve: prefill shape + decode step shape, all finite
    logits, caches = jax.jit(lambda p, b: api.prefill(p, b, cfg, cache_len))(params, bd)
    assert logits.shape == (batch, cfg.padded_vocab), (arch, logits.shape)
    assert np.isfinite(np.asarray(logits, np.float32)[:, : cfg.vocab]).all()
    tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
    lg2, _ = jax.jit(
        lambda p, t, c, pos: api.decode_step(p, t, c, pos, cfg)
    )(params, tok, caches, jnp.int32(seq))
    assert lg2.shape == (batch, cfg.padded_vocab), arch
    assert np.isfinite(np.asarray(lg2, np.float32)[:, : cfg.vocab]).all()


def test_full_configs_param_counts():
    """Full configs match their nameplate sizes (backbone-only for VLM)."""
    expect = {
        "starcoder2-15b": (14.0, 17.5),
        "llama3.2-3b": (2.8, 3.7),
        "qwen2-1.5b": (1.3, 1.8),
        "minicpm3-4b": (3.8, 4.8),
        "whisper-large-v3": (1.4, 1.7),
        "moonshot-v1-16b-a3b": (25.0, 30.0),  # assignment's 48L spec
        "qwen3-moe-30b-a3b": (28.0, 32.0),
        "mamba2-2.7b": (2.4, 3.1),
        "jamba-1.5-large-398b": (380.0, 410.0),
        "internvl2-26b": (18.0, 21.0),  # InternLM2-20B backbone (ViT stubbed)
    }
    for arch, (lo, hi) in expect.items():
        cfg = configs.get_config(arch).model
        n = meta.count_params(api.template(cfg)) / 1e9
        assert lo <= n <= hi, (arch, n)
