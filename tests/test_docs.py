"""Documentation conformance:

  * every ```python block in docs/*.md and README.md executes cleanly
    (the examples in docs/api.md are real, asserted programs);
  * every `file.py:symbol` anchor in docs/paper_map.md points at a file
    that exists and a symbol defined in it (the paper↔code map cannot
    silently rot as the tree is refactored);
  * doctests in the public core modules pass (the CI doctest leg runs
    the full ``--doctest-modules`` sweep; this keeps a fast local
    subset in tier-1).
"""

import doctest
import pathlib
import re

import pytest

ROOT = pathlib.Path(__file__).resolve().parent.parent

_DOC_FILES = sorted(
    p for p in [*(ROOT / "docs").glob("*.md"), ROOT / "README.md"]
    if p.exists()
)


def extract_python_blocks(path: pathlib.Path):
    """All ```python fenced blocks of a markdown file, with line info."""
    text = path.read_text()
    blocks = []
    for m in re.finditer(r"```python\n(.*?)```", text, re.DOTALL):
        line = text[: m.start()].count("\n") + 2
        blocks.append((line, m.group(1)))
    return blocks


_SNIPPETS = [
    pytest.param(path, line, code, id=f"{path.name}:L{line}")
    for path in _DOC_FILES
    for line, code in extract_python_blocks(path)
]


@pytest.mark.parametrize("path,line,code", _SNIPPETS)
def test_doc_snippet_runs(path, line, code):
    """Each doc example is a self-contained program with its own
    assertions; a failure points at <file>:L<line>."""
    namespace = {"__name__": f"docsnippet_{path.stem}_L{line}"}
    exec(compile(code, f"{path.name}:L{line}", "exec"), namespace)


# ----------------------------------------------------------------------
# paper_map.md anchors
# ----------------------------------------------------------------------

_ANCHOR_RE = re.compile(r"`((?:src|tests|benchmarks|examples)/[\w/]+\.py):([A-Za-z_]\w*)`")


def _paper_map_anchors():
    text = (ROOT / "docs" / "paper_map.md").read_text()
    anchors = sorted(set(_ANCHOR_RE.findall(text)))
    assert anchors, "docs/paper_map.md must contain file:symbol anchors"
    return anchors


@pytest.mark.parametrize(
    "rel,symbol", _paper_map_anchors(), ids=lambda v: str(v)
)
def test_paper_map_anchor_exists(rel, symbol):
    path = ROOT / rel
    assert path.exists(), f"paper_map.md references missing file {rel}"
    src = path.read_text()
    pattern = re.compile(
        rf"^\s*(?:def|class)\s+{re.escape(symbol)}\b|^{re.escape(symbol)}\s*=",
        re.MULTILINE,
    )
    assert pattern.search(src), (
        f"paper_map.md references {rel}:{symbol}, not defined there"
    )


def test_paper_map_covers_all_nine_steps():
    text = (ROOT / "docs" / "paper_map.md").read_text()
    table = [ln for ln in text.splitlines() if ln.startswith("|")]
    steps = [ln for ln in table if re.match(r"\|\s*[1-9]\s*\|", ln)]
    assert len(steps) == 9, f"expected 9 algorithm-step rows, got {len(steps)}"


# ----------------------------------------------------------------------
# doctests (fast local subset; CI runs the full --doctest-modules leg)
# ----------------------------------------------------------------------


@pytest.mark.parametrize(
    "module_name",
    ["repro.core.key_codec", "repro.core.bucket_sort",
     "repro.core.partial_sort", "repro.core.probe"],
)
def test_module_doctests(module_name):
    import importlib

    mod = importlib.import_module(module_name)
    results = doctest.testmod(mod, verbose=False)
    assert results.failed == 0, f"{results.failed} doctest failures in {module_name}"
    assert results.attempted > 0, f"no doctests collected from {module_name}"
