"""Baselines (randomized sample sort, merge sort, xla sort)."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import baselines
from repro.core.sort_config import SortConfig

CFG = SortConfig(tile=256, s=16, direct_max=512, impl="xla")


def test_randomized_sample_sort_uniform(rng):
    x = jnp.asarray(rng.integers(-(10**9), 10**9, 40_000).astype(np.int32))
    srt, perm, (maxfill, ovf) = baselines.randomized_sample_sort(
        x, jax.random.PRNGKey(0), CFG, capacity_factor=4.0, with_stats=True
    )
    assert int(ovf) == 0
    np.testing.assert_array_equal(np.asarray(srt), np.sort(np.asarray(x)))


def test_randomized_bucket_variance_exceeds_deterministic(rng):
    """C2: randomized bucket sizes fluctuate run-to-run; deterministic
    bucket sizes are fixed."""
    from repro.core import bucket_sort

    x = jnp.asarray((rng.zipf(1.3, 30_000) % 10**6).astype(np.int32))
    fills = []
    for seed in range(5):
        _, _, (maxfill, _) = baselines.randomized_sample_sort(
            x, jax.random.PRNGKey(seed), CFG, capacity_factor=8.0,
            with_stats=True, max_attempts=1,  # raw mode: observe fills as-is
        )
        fills.append(int(maxfill))
    assert len(set(fills)) > 1, "randomized fills should vary with seed"
    det = [
        int(np.asarray(bucket_sort.sort_with_stats(x, CFG)[2][0]["totals"]).max())
        for _ in range(2)
    ]
    assert det[0] == det[1], "deterministic fills must not vary"


def test_merge_sort(rng):
    x = jnp.asarray(rng.integers(-(10**9), 10**9, 10_000).astype(np.int32))
    srt, perm = baselines.merge_sort(x, CFG)
    np.testing.assert_array_equal(np.asarray(srt), np.sort(np.asarray(x)))
    xd = jnp.asarray(rng.integers(0, 5, 3000).astype(np.int32))
    _, p = baselines.merge_sort(xd, CFG)
    np.testing.assert_array_equal(np.asarray(p), np.argsort(np.asarray(xd), kind="stable"))


def test_xla_sort(rng):
    x = jnp.asarray(rng.normal(size=5000).astype(np.float32))
    srt, perm = baselines.xla_sort(x)
    np.testing.assert_array_equal(np.asarray(srt), np.sort(np.asarray(x)))
