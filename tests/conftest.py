# NOTE: no XLA_FLAGS here on purpose — unit tests and benches must see
# the real single CPU device; only launch/dryrun.py forces 512 host
# devices (and distributed tests spawn subprocesses with their own env).
import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)
