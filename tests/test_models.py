"""Model-layer correctness: attention vs naive reference, mamba SSD vs
naive recurrence, MoE dispatch equivalence across impls."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import LayerSlot, ModelConfig, MoEConfig, SSMConfig
from repro.models import attention as A
from repro.models import mamba2 as M
from repro.models import moe as MOE
from repro.models.meta import init_params


# ----------------------------------------------------------- attention
def naive_attention(q, k, v, causal):
    b, sq, h, d = q.shape
    kh = k.shape[2]
    g = h // kh
    kk = np.repeat(np.asarray(k, np.float32), g, axis=2)
    vv = np.repeat(np.asarray(v, np.float32), g, axis=2)
    qq = np.asarray(q, np.float32)
    s = np.einsum("bqhd,bkhd->bhqk", qq, kk) / np.sqrt(d)
    if causal:
        mask = np.tril(np.ones((sq, k.shape[1]), bool))
        s = np.where(mask[None, None], s, -np.inf)
    p = np.exp(s - s.max(-1, keepdims=True))
    p = p / p.sum(-1, keepdims=True)
    return np.einsum("bhqk,bkhd->bqhd", p, vv)


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("sq,chunk", [(64, 16), (64, 64), (60, 16)])
def test_chunked_attention_matches_naive(rng, causal, sq, chunk):
    b, h, kh, d = 2, 4, 2, 16
    q = jnp.asarray(rng.normal(size=(b, sq, h, d)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(b, sq, kh, d)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(b, sq, kh, d)).astype(np.float32))
    out = A.chunked_attention(q, k, v, chunk=chunk, causal=causal)
    ref = naive_attention(q, k, v, causal)
    np.testing.assert_allclose(np.asarray(out), ref, rtol=2e-5, atol=2e-5)


def test_chunked_attention_unroll_equals_scan(rng):
    b, s, h, kh, d = 1, 64, 4, 2, 16
    q = jnp.asarray(rng.normal(size=(b, s, h, d)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(b, s, kh, d)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(b, s, kh, d)).astype(np.float32))
    a = A.chunked_attention(q, k, v, chunk=16, causal=True, unroll=False)
    b_ = A.chunked_attention(q, k, v, chunk=16, causal=True, unroll=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b_), rtol=1e-6, atol=1e-6)


# ----------------------------------------------------------- mamba SSD
def naive_ssd(x, dt, a, b, c, d_skip):
    """Sequential recurrence oracle.  x:(B,S,H,P) dt:(B,S,H) a:(H,)
    b,c:(B,S,G,N)."""
    bs, s, h, p = x.shape
    g, n = b.shape[2], b.shape[3]
    hpg = h // g
    y = np.zeros_like(x, dtype=np.float64)
    st = np.zeros((bs, h, p, n), np.float64)
    for t in range(s):
        dec = np.exp(dt[:, t] * a[None, :])  # (B,H)
        bh = np.repeat(b[:, t], hpg, axis=1)  # (B,H,N)
        ch = np.repeat(c[:, t], hpg, axis=1)
        st = st * dec[..., None, None] + np.einsum(
            "bh,bhp,bhn->bhpn", dt[:, t], x[:, t], bh
        )
        y[:, t] = np.einsum("bhpn,bhn->bhp", st, ch) + d_skip[None, :, None] * x[:, t]
    return y, st


def _mamba_cfg():
    return ModelConfig(
        name="m", n_layers=1, d_model=32, n_heads=1, n_kv_heads=1, d_ff=0,
        vocab=64, layer_pattern=(LayerSlot("mamba", "none"),),
        ssm=SSMConfig(d_state=8, d_conv=4, expand=2, head_dim=8, chunk=8),
        param_dtype="float32", dtype="float32", scan_layers=True,
    )


def test_ssd_chunked_matches_naive_recurrence(rng):
    cfg = _mamba_cfg()
    ss = cfg.ssm
    d_inner, n_heads, conv_dim, _ = M.dims(cfg)
    bsz, s = 2, 32
    x = rng.normal(size=(bsz, s, n_heads, ss.head_dim)).astype(np.float32)
    dt = np.abs(rng.normal(size=(bsz, s, n_heads))).astype(np.float32) * 0.5
    a = -np.abs(rng.normal(size=(n_heads,))).astype(np.float32)
    b = rng.normal(size=(bsz, s, ss.n_groups, ss.d_state)).astype(np.float32)
    c = rng.normal(size=(bsz, s, ss.n_groups, ss.d_state)).astype(np.float32)
    dskip = rng.normal(size=(n_heads,)).astype(np.float32)

    # chunked path, extracted from mamba_forward's math
    q = ss.chunk
    nc = s // q
    da = dt * a[None, None, :]
    dac = da.reshape(bsz, nc, q, n_heads)
    da_cs = np.cumsum(dac, axis=2)
    xdt = (x * dt[..., None]).reshape(bsz, nc, q, n_heads, ss.head_dim)
    bc = b.reshape(bsz, nc, q, ss.n_groups, ss.d_state)
    cc = c.reshape(bsz, nc, q, ss.n_groups, ss.d_state)
    li = da_cs[:, :, :, None, :] - da_cs[:, :, None, :, :]
    mask = (np.arange(q)[:, None] >= np.arange(q)[None, :])[None, None, :, :, None]
    l = np.where(mask, np.exp(np.where(mask, li, 0.0)), 0.0)
    hpg = n_heads // ss.n_groups
    cb = np.repeat(np.einsum("bcign,bcjgn->bcijg", cc, bc), hpg, axis=-1)
    y_diag = np.einsum("bcijh,bcijh,bcjhp->bcihp", cb, l, xdt)
    decay_states = np.exp(da_cs[:, :, -1:, :] - da_cs)
    states = np.einsum("bcqgn,bcqh,bcqhp->bchpn", bc, decay_states, xdt)
    chunk_decay = np.exp(da_cs[:, :, -1, :])
    h = np.zeros((bsz, n_heads, ss.head_dim, ss.d_state))
    hs = []
    for i in range(nc):
        hs.append(h)
        h = h * chunk_decay[:, i][..., None, None] + states[:, i]
    h_starts = np.stack(hs, axis=1)
    cch = np.repeat(cc, hpg, axis=3)
    y_off = np.einsum("bcqhn,bchpn,bcqh->bcqhp", cch, h_starts, np.exp(da_cs))
    y_chunked = (y_diag + y_off).reshape(bsz, s, n_heads, ss.head_dim) + \
        dskip[None, None, :, None] * x

    y_naive, st_naive = naive_ssd(x, dt, a, b, c, dskip)
    np.testing.assert_allclose(y_chunked, y_naive, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(h, st_naive, rtol=1e-4, atol=1e-4)


def test_mamba_decode_matches_forward(rng):
    cfg = _mamba_cfg()
    p = init_params(M.mamba_template(cfg), jax.random.PRNGKey(0))
    bsz, s = 2, 16
    x = jnp.asarray(rng.normal(size=(bsz, s, cfg.d_model)).astype(np.float32))
    y_full, cache = M.mamba_forward(p, x, cfg, return_state=True)
    # replay through decode steps
    dcache = M.mamba_init_cache(cfg, bsz, jnp.float32)
    ys = []
    for t in range(s):
        y, dcache = M.mamba_decode(p, x[:, t : t + 1], cfg, dcache)
        ys.append(y)
    y_step = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(
        np.asarray(y_step), np.asarray(y_full), rtol=2e-4, atol=2e-4
    )
    np.testing.assert_allclose(
        np.asarray(dcache["ssm"]), np.asarray(cache["ssm"]), rtol=2e-4, atol=2e-4
    )


# ----------------------------------------------------------------- MoE
def _moe_cfg(dispatch):
    return ModelConfig(
        name="moe", n_layers=1, d_model=32, n_heads=2, n_kv_heads=2, d_ff=64,
        vocab=64, layer_pattern=(LayerSlot("attn", "moe"),),
        moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=16,
                      capacity_factor=8.0, dispatch=dispatch),
        param_dtype="float32", dtype="float32",
    )


def test_moe_dispatch_impls_agree(rng):
    cfgs = {d: _moe_cfg(d) for d in ("sample_sort", "xla_sort", "onehot")}
    p = init_params(MOE.moe_template(cfgs["sample_sort"]), jax.random.PRNGKey(1))
    x = jnp.asarray(rng.normal(size=(2, 16, 32)).astype(np.float32))
    outs = {}
    for d, cfg in cfgs.items():
        y, aux = MOE.moe_apply(p, x, cfg)
        outs[d] = np.asarray(y)
        assert np.isfinite(outs[d]).all()
    np.testing.assert_allclose(outs["sample_sort"], outs["xla_sort"], rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(outs["sample_sort"], outs["onehot"], rtol=1e-5, atol=1e-5)


def test_moe_capacity_drops_are_masked(rng):
    cfg = _moe_cfg("sample_sort")
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=0.05)
    )
    p = init_params(MOE.moe_template(cfg), jax.random.PRNGKey(1))
    x = jnp.asarray(rng.normal(size=(2, 64, 32)).astype(np.float32))
    y, aux = MOE.moe_apply(p, x, cfg)
    assert np.isfinite(np.asarray(y)).all()


def test_moe_dense_vs_sorted_dispatch_reference(rng):
    """Sorted dispatch == brute-force per-expert gather reference."""
    cfg = _moe_cfg("sample_sort")
    p = init_params(MOE.moe_template(cfg), jax.random.PRNGKey(2))
    x = jnp.asarray(rng.normal(size=(1, 32, 32)).astype(np.float32))
    y, _ = MOE.moe_apply(p, x, cfg)
    # reference: explicit loop over tokens/experts
    xf = np.asarray(x).reshape(32, 32)
    logits = xf @ np.asarray(p["router"], np.float32)
    probs = np.exp(logits - logits.max(-1, keepdims=True))
    probs = probs / probs.sum(-1, keepdims=True)
    ref = np.zeros_like(xf)
    for t in range(32):
        top = np.argsort(-probs[t])[: cfg.moe.top_k]
        w = probs[t, top] / probs[t, top].sum()
        for e, wi in zip(top, w):
            g = xf[t] @ np.asarray(p["wg"][e])
            u = xf[t] @ np.asarray(p["wu"][e])
            h = (g / (1 + np.exp(-g))) * u
            ref[t] += wi * (h @ np.asarray(p["wd"][e]))
    np.testing.assert_allclose(np.asarray(y).reshape(32, 32), ref, rtol=2e-4, atol=2e-4)
