"""Differential conformance suite: one parametrized harness asserting
every public sort API agrees with the jnp oracles (jnp.sort /
jnp.argsort / jax.lax.top_k) across

  * dtypes: int32 / uint32 / float32 incl. NaN, +/-inf, -0.0;
  * sizes crossing every cell's ``direct_max`` and tile boundaries;
  * both relocation paths (scatter-free gather + legacy scatter);
  * impl="xla" and interpreted Pallas.

No xfails anywhere: every (api, dtype, impl, relocation) cell must pass.

Float caveats, pinned down so the oracle comparison is EXACT:
  * Our total order ranks sign-bit ("negative") NaNs first; jnp.sort
    follows numpy and puts ALL NaNs last.  Inputs here use np.nan — a
    positive quiet NaN — whose single bit pattern both orders place
    last, stably by index.
  * Our total order ranks -0.0 < +0.0 strictly; numpy/jnp treat them as
    equal (stable) keys.  Value comparisons are unaffected
    (assert_array_equal treats -0.0 == +0.0), so ``sort`` inputs
    include -0.0; exact PERMUTATION comparisons (argsort) drop it.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bucket_sort, partial_sort
from repro.core.sort_config import SortConfig

_XLA = SortConfig(tile=256, s=16, direct_max=512, impl="xla")
_PAL = SortConfig(tile=128, s=8, direct_max=256, impl="pallas", interpret=True)

CELLS = [
    pytest.param(_XLA, id="xla-gather"),
    pytest.param(dataclasses.replace(_XLA, relocation="scatter"),
                 id="xla-scatter"),
    pytest.param(_PAL, id="pallas-gather"),
    pytest.param(dataclasses.replace(_PAL, relocation="scatter"),
                 id="pallas-scatter"),
]

# Crosses both cells' tile (128/256) and direct_max (256/512) boundaries.
SIZES = [1, 5, 127, 128, 255, 256, 511, 512, 513, 1500]

DTYPES = ["int32", "uint32", "float32"]


def make_keys(dtype, n, rng, *, signed_zero=True):
    """Adversarial-ish keys: full-range ints / floats spiked with the
    special values (NaN always np.nan — see module docstring)."""
    if dtype == "int32":
        return rng.integers(-(2**31), 2**31 - 1, n).astype(np.int32)
    if dtype == "uint32":
        return rng.integers(0, 2**32, n, dtype=np.uint64).astype(np.uint32)
    x = (rng.normal(size=n) * rng.choice([1e-30, 1.0, 1e30], n)).astype(
        np.float32
    )
    specials = [np.nan, np.inf, -np.inf, 0.0] + ([-0.0] if signed_zero else [])
    idx = rng.integers(0, n, min(n, 25))
    x[idx] = np.asarray(specials, np.float32)[
        rng.integers(0, len(specials), len(idx))
    ]
    return x


@pytest.mark.parametrize("cfg", CELLS)
@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("n", SIZES)
def test_sort_matches_jnp(rng, cfg, dtype, n):
    x = make_keys(dtype, n, rng)
    got = np.asarray(bucket_sort.sort(jnp.asarray(x), cfg))
    want = np.asarray(jnp.sort(jnp.asarray(x)))
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("cfg", CELLS)
@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("n", SIZES)
def test_argsort_matches_jnp(rng, cfg, dtype, n):
    x = make_keys(dtype, n, rng, signed_zero=False)
    got = np.asarray(bucket_sort.argsort(jnp.asarray(x), cfg))
    want = np.asarray(jnp.argsort(jnp.asarray(x), stable=True))
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("cfg", CELLS)
@pytest.mark.parametrize("dtype", DTYPES)
def test_sort_kv_matches_jnp_permutation(rng, cfg, dtype):
    n = 700  # crosses both cells' direct_max
    x = make_keys(dtype, n, rng, signed_zero=False)
    vals = rng.normal(size=(n, 3)).astype(np.float32)
    sk, sv = bucket_sort.sort_kv(jnp.asarray(x), jnp.asarray(vals), cfg)
    perm = np.asarray(jnp.argsort(jnp.asarray(x), stable=True))
    np.testing.assert_array_equal(np.asarray(sk), np.asarray(jnp.sort(jnp.asarray(x))))
    np.testing.assert_array_equal(np.asarray(sv), vals[perm])


@pytest.mark.parametrize("cfg", CELLS)
@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("length", [40, 700])  # direct path + bucket round
def test_batched_matches_jnp_rows(rng, cfg, dtype, length):
    b = 5  # odd batch: exercises the row_pad path on pallas cells
    x = np.stack([make_keys(dtype, length, rng, signed_zero=False)
                  for _ in range(b)])
    xj = jnp.asarray(x)
    got = np.asarray(bucket_sort.sort_batched(xj, cfg))
    np.testing.assert_array_equal(got, np.asarray(jnp.sort(xj, axis=-1)))
    gotp = np.asarray(bucket_sort.argsort_batched(xj, cfg))
    np.testing.assert_array_equal(
        gotp, np.asarray(jnp.argsort(xj, axis=-1, stable=True))
    )


@pytest.mark.parametrize("cfg", CELLS)
def test_sort_kv_batched_matches_jnp_rows(rng, cfg):
    b, length = 4, 700
    x = rng.integers(0, 50, (b, length)).astype(np.int32)  # heavy ties
    vals = rng.normal(size=(b, length, 2)).astype(np.float32)
    sk, sv = bucket_sort.sort_kv_batched(
        jnp.asarray(x), jnp.asarray(vals), cfg
    )
    perm = np.argsort(x, axis=-1, kind="stable")
    np.testing.assert_array_equal(np.asarray(sk), np.sort(x, axis=-1))
    np.testing.assert_array_equal(
        np.asarray(sv), np.take_along_axis(vals, perm[:, :, None], axis=1)
    )


@pytest.mark.parametrize("cfg", CELLS)
@pytest.mark.parametrize("dtype", DTYPES)
def test_segmented_matches_jnp_per_segment(rng, cfg, dtype):
    n = 1200
    x = make_keys(dtype, n, rng, signed_zero=False)
    # empty, single-element, and > direct_max segments
    off = [0, 0, 1, 5, 600, 600, 900, n]
    xj = jnp.asarray(x)
    got = np.asarray(bucket_sort.segment_sort(xj, off, cfg))
    gotp = np.asarray(bucket_sort.segment_argsort(xj, off, cfg))
    for lo, hi in zip(off, off[1:]):
        seg = jnp.asarray(x[lo:hi])
        np.testing.assert_array_equal(got[lo:hi], np.asarray(jnp.sort(seg)))
        np.testing.assert_array_equal(
            gotp[lo:hi], lo + np.asarray(jnp.argsort(seg, stable=True))
        )


@pytest.mark.parametrize("cfg", CELLS)
@pytest.mark.parametrize("dtype", ["int32", "float32"])
@pytest.mark.parametrize("n", [300, 1500])  # direct path + partial round
def test_topk_matches_lax(rng, cfg, dtype, n):
    k = 16
    if dtype == "int32":
        x = rng.integers(-(2**31), 2**31 - 1, n).astype(np.int32)
    else:
        x = rng.normal(size=n).astype(np.float32)
        x[rng.integers(0, n, 5)] = np.asarray(
            [np.inf, -np.inf, 0.0, 1.0, -1.0], np.float32
        )
    tv, ti = partial_sort.topk(jnp.asarray(x), k, cfg)
    lv, li = jax.lax.top_k(jnp.asarray(x), k)
    np.testing.assert_array_equal(np.asarray(tv), np.asarray(lv))
    np.testing.assert_array_equal(np.asarray(ti), np.asarray(li))


@pytest.mark.parametrize("cfg", CELLS)
@pytest.mark.parametrize("dtype", ["int32", "float32"])
@pytest.mark.parametrize("n", [300, 1500])  # direct path + partial round
def test_topk_batched_matches_lax(rng, cfg, dtype, n):
    b, k = 5, 16
    if dtype == "int32":
        x = rng.integers(0, 40, (b, n)).astype(np.int32)  # heavy ties
    else:
        x = rng.normal(size=(b, n)).astype(np.float32)
    tv, ti = partial_sort.topk_batched(jnp.asarray(x), k, cfg)
    lv, li = jax.lax.top_k(jnp.asarray(x), k)
    np.testing.assert_array_equal(np.asarray(tv), np.asarray(lv))
    np.testing.assert_array_equal(np.asarray(ti), np.asarray(li))
