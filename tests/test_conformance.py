"""Differential conformance suite: one parametrized harness asserting
every public sort API agrees with the jnp oracles (jnp.sort /
jnp.argsort / jax.lax.top_k) across

  * dtypes: int32 / uint32 / float32 / int64 / uint64 / float64 /
    bfloat16 / bool incl. NaN, +/-inf, -0.0 (64-bit dtypes run under
    the enable_x64 context — see the ``x64`` fixture);
  * ascending AND descending (``SortConfig.descending``, vs the
    ``jnp.sort(..., descending=True)`` oracles);
  * sizes crossing every cell's ``direct_max`` and tile boundaries;
  * both relocation paths (scatter-free gather + legacy scatter);
  * impl="xla" and interpreted Pallas.

No xfails anywhere: every (api, dtype, order, impl, relocation) cell
must pass.

Float caveats, pinned down so the oracle comparison is EXACT:
  * Our total order ranks sign-bit ("negative") NaNs first; jnp.sort
    follows numpy and puts ALL NaNs last.  Inputs here use np.nan — a
    positive quiet NaN — whose single bit pattern both orders place
    last (first when descending), stably by index.
  * Our total order ranks -0.0 < +0.0 strictly; numpy/jnp treat them as
    equal (stable) keys.  Value comparisons are unaffected
    (assert_array_equal treats -0.0 == +0.0), so ``sort`` inputs
    include -0.0; exact PERMUTATION comparisons (argsort) drop it.
"""

import contextlib
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bucket_sort, partial_sort
from repro.core.sort_config import SortConfig

_XLA = SortConfig(tile=256, s=16, direct_max=512, impl="xla")
_PAL = SortConfig(tile=128, s=8, direct_max=256, impl="pallas", interpret=True)

CELLS = [
    pytest.param(_XLA, id="xla-gather"),
    pytest.param(dataclasses.replace(_XLA, relocation="scatter"),
                 id="xla-scatter"),
    pytest.param(_PAL, id="pallas-gather"),
    pytest.param(dataclasses.replace(_PAL, relocation="scatter"),
                 id="pallas-scatter"),
]

# Crosses both cells' tile (128/256) and direct_max (256/512) boundaries.
SIZES = [1, 5, 127, 128, 255, 256, 511, 512, 513, 1500]

DTYPES = ["int32", "uint32", "float32"]
# Key-codec satellites: two-word 64-bit keys, widened bf16/bool.  Run
# through the SAME assertions as the core 32-bit dtypes.
WIDE_DTYPES = ["int64", "uint64", "float64", "bfloat16", "bool"]
ALL_DTYPES = DTYPES + WIDE_DTYPES

ORDERS = ["asc", "desc"]


def dtype_ctx(dtype):
    """enable_x64 context for the 64-bit dtypes, no-op otherwise."""
    if dtype in ("int64", "uint64", "float64"):
        return jax.experimental.enable_x64()
    return contextlib.nullcontext()


def order_cfg(cfg, order):
    return dataclasses.replace(cfg, descending=(order == "desc"))


def make_keys(dtype, n, rng, *, signed_zero=True):
    """Adversarial-ish keys: full-range ints / floats spiked with the
    special values (NaN always np.nan — see module docstring)."""
    if dtype == "int32":
        return rng.integers(-(2**31), 2**31 - 1, n).astype(np.int32)
    if dtype == "uint32":
        return rng.integers(0, 2**32, n, dtype=np.uint64).astype(np.uint32)
    if dtype == "int64":
        return rng.integers(-(2**63), 2**63 - 1, n, dtype=np.int64)
    if dtype == "uint64":
        return rng.integers(0, 2**64, n, dtype=np.uint64)
    if dtype == "bool":
        return rng.integers(0, 2, n).astype(bool)
    # bfloat16 is generated as float32 (specials included below) and cast
    # at the jnp boundary by make_jnp_keys — NaN/±inf/±0.0 are exact in
    # bf16 and finite normals round to valid bf16 ties.
    ftype = np.float64 if dtype == "float64" else np.float32
    big = 1e300 if dtype == "float64" else 1e30
    x = (rng.normal(size=n) * rng.choice([1.0 / big, 1.0, big], n)).astype(
        ftype
    )
    specials = [np.nan, np.inf, -np.inf, 0.0] + ([-0.0] if signed_zero else [])
    idx = rng.integers(0, n, min(n, 25))
    x[idx] = np.asarray(specials, ftype)[
        rng.integers(0, len(specials), len(idx))
    ]
    return x


def npc(a):
    """numpy view for comparisons: bfloat16 -> float32 (numpy's NaN-aware
    assert helpers don't understand ml_dtypes scalars; the f32 embedding
    is exact, so equality semantics are unchanged)."""
    a = np.asarray(a)
    if a.dtype == jnp.bfloat16:
        return a.astype(np.float32)
    return a


def make_jnp_keys(dtype, n, rng, *, signed_zero=True):
    """jnp array of ``dtype`` (inside the right x64 context)."""
    x = jnp.asarray(make_keys(dtype, n, rng, signed_zero=signed_zero))
    if dtype == "bfloat16":
        x = x.astype(jnp.bfloat16)
    return x


@pytest.mark.parametrize("cfg", CELLS)
@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("n", SIZES)
def test_sort_matches_jnp(rng, cfg, dtype, n):
    x = make_keys(dtype, n, rng)
    got = np.asarray(bucket_sort.sort(jnp.asarray(x), cfg))
    want = np.asarray(jnp.sort(jnp.asarray(x)))
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("cfg", CELLS)
@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("n", SIZES)
def test_argsort_matches_jnp(rng, cfg, dtype, n):
    x = make_keys(dtype, n, rng, signed_zero=False)
    got = np.asarray(bucket_sort.argsort(jnp.asarray(x), cfg))
    want = np.asarray(jnp.argsort(jnp.asarray(x), stable=True))
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("cfg", CELLS)
@pytest.mark.parametrize("dtype", ALL_DTYPES)
@pytest.mark.parametrize("order", ORDERS)
@pytest.mark.parametrize("n", [5, 700])  # direct path + bucket round
def test_sort_all_dtypes_both_orders(rng, cfg, dtype, order, n):
    """The key-codec matrix: every codec dtype, ascending and
    descending, vs the jnp.sort oracle (values; NaN/±inf/-0.0 in)."""
    desc = order == "desc"
    with dtype_ctx(dtype):
        x = make_jnp_keys(dtype, n, rng)
        got = npc(bucket_sort.sort(x, order_cfg(cfg, order)))
        want = npc(jnp.sort(x, descending=desc))
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("cfg", CELLS)
@pytest.mark.parametrize("dtype", ALL_DTYPES)
@pytest.mark.parametrize("order", ORDERS)
@pytest.mark.parametrize("n", [5, 700])
def test_argsort_all_dtypes_both_orders(rng, cfg, dtype, order, n):
    """Exact stable permutations for the full codec matrix (signed
    zeros dropped — see module docstring)."""
    desc = order == "desc"
    with dtype_ctx(dtype):
        x = make_jnp_keys(dtype, n, rng, signed_zero=False)
        got = np.asarray(bucket_sort.argsort(x, order_cfg(cfg, order)))
        want = np.asarray(jnp.argsort(x, stable=True, descending=desc))
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("cfg", CELLS)
@pytest.mark.parametrize("dtype", ALL_DTYPES)
@pytest.mark.parametrize("order", ORDERS)
def test_sort_kv_matches_jnp_permutation(rng, cfg, dtype, order):
    n = 700  # crosses both cells' direct_max
    desc = order == "desc"
    with dtype_ctx(dtype):
        x = make_jnp_keys(dtype, n, rng, signed_zero=False)
        vals = rng.normal(size=(n, 3)).astype(np.float32)
        sk, sv = bucket_sort.sort_kv(x, jnp.asarray(vals),
                                     order_cfg(cfg, order))
        perm = np.asarray(jnp.argsort(x, stable=True, descending=desc))
        want_k = npc(jnp.sort(x, descending=desc))
        got_k, got_v = npc(sk), np.asarray(sv)
    np.testing.assert_array_equal(got_k, want_k)
    np.testing.assert_array_equal(got_v, vals[perm])


@pytest.mark.parametrize("cfg", CELLS)
@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("length", [40, 700])  # direct path + bucket round
def test_batched_matches_jnp_rows(rng, cfg, dtype, length):
    b = 5  # odd batch: exercises the row_pad path on pallas cells
    x = np.stack([make_keys(dtype, length, rng, signed_zero=False)
                  for _ in range(b)])
    xj = jnp.asarray(x)
    got = np.asarray(bucket_sort.sort_batched(xj, cfg))
    np.testing.assert_array_equal(got, np.asarray(jnp.sort(xj, axis=-1)))
    gotp = np.asarray(bucket_sort.argsort_batched(xj, cfg))
    np.testing.assert_array_equal(
        gotp, np.asarray(jnp.argsort(xj, axis=-1, stable=True))
    )


@pytest.mark.parametrize("cfg", CELLS)
@pytest.mark.parametrize("dtype", ALL_DTYPES)
@pytest.mark.parametrize("order", ORDERS)
def test_batched_all_dtypes_both_orders(rng, cfg, dtype, order):
    """sort_batched/argsort_batched over the full codec matrix."""
    b, length = 5, 700
    desc = order == "desc"
    with dtype_ctx(dtype):
        x = jnp.stack([make_jnp_keys(dtype, length, rng, signed_zero=False)
                       for _ in range(b)])
        c = order_cfg(cfg, order)
        got = npc(bucket_sort.sort_batched(x, c))
        want = npc(jnp.sort(x, axis=-1, descending=desc))
        gotp = np.asarray(bucket_sort.argsort_batched(x, c))
        wantp = np.asarray(
            jnp.argsort(x, axis=-1, stable=True, descending=desc)
        )
    np.testing.assert_array_equal(got, want)
    np.testing.assert_array_equal(gotp, wantp)


@pytest.mark.parametrize("cfg", CELLS)
def test_sort_kv_batched_matches_jnp_rows(rng, cfg):
    b, length = 4, 700
    x = rng.integers(0, 50, (b, length)).astype(np.int32)  # heavy ties
    vals = rng.normal(size=(b, length, 2)).astype(np.float32)
    sk, sv = bucket_sort.sort_kv_batched(
        jnp.asarray(x), jnp.asarray(vals), cfg
    )
    perm = np.argsort(x, axis=-1, kind="stable")
    np.testing.assert_array_equal(np.asarray(sk), np.sort(x, axis=-1))
    np.testing.assert_array_equal(
        np.asarray(sv), np.take_along_axis(vals, perm[:, :, None], axis=1)
    )


@pytest.mark.parametrize("cfg", CELLS)
@pytest.mark.parametrize("dtype", DTYPES)
def test_segmented_matches_jnp_per_segment(rng, cfg, dtype):
    n = 1200
    x = make_keys(dtype, n, rng, signed_zero=False)
    # empty, single-element, and > direct_max segments
    off = [0, 0, 1, 5, 600, 600, 900, n]
    xj = jnp.asarray(x)
    got = np.asarray(bucket_sort.segment_sort(xj, off, cfg))
    gotp = np.asarray(bucket_sort.segment_argsort(xj, off, cfg))
    for lo, hi in zip(off, off[1:]):
        seg = jnp.asarray(x[lo:hi])
        np.testing.assert_array_equal(got[lo:hi], np.asarray(jnp.sort(seg)))
        np.testing.assert_array_equal(
            gotp[lo:hi], lo + np.asarray(jnp.argsort(seg, stable=True))
        )


@pytest.mark.parametrize("cfg", CELLS)
@pytest.mark.parametrize("dtype", ["int64", "float64", "bool"])
@pytest.mark.parametrize("order", ORDERS)
def test_segmented_wide_dtypes_both_orders(rng, cfg, dtype, order):
    """segment_sort/segment_argsort over codec satellites + descending."""
    n = 1200
    desc = order == "desc"
    off = [0, 0, 1, 5, 600, 600, 900, n]
    with dtype_ctx(dtype):
        x = make_jnp_keys(dtype, n, rng, signed_zero=False)
        c = order_cfg(cfg, order)
        got = np.asarray(bucket_sort.segment_sort(x, off, c))
        gotp = np.asarray(bucket_sort.segment_argsort(x, off, c))
        want, wantp = [], []
        for lo, hi in zip(off, off[1:]):
            want.append(np.asarray(jnp.sort(x[lo:hi], descending=desc)))
            wantp.append(lo + np.asarray(
                jnp.argsort(x[lo:hi], stable=True, descending=desc)
            ))
    for (lo, hi), w, wp in zip(zip(off, off[1:]), want, wantp):
        np.testing.assert_array_equal(got[lo:hi], w)
        np.testing.assert_array_equal(gotp[lo:hi], wp)


@pytest.mark.parametrize("cfg", CELLS)
@pytest.mark.parametrize("dtype", ["int32", "float32"])
@pytest.mark.parametrize("n", [300, 1500])  # direct path + partial round
def test_topk_matches_lax(rng, cfg, dtype, n):
    k = 16
    if dtype == "int32":
        x = rng.integers(-(2**31), 2**31 - 1, n).astype(np.int32)
    else:
        x = rng.normal(size=n).astype(np.float32)
        x[rng.integers(0, n, 5)] = np.asarray(
            [np.inf, -np.inf, 0.0, 1.0, -1.0], np.float32
        )
    tv, ti = partial_sort.topk(jnp.asarray(x), k, cfg)
    lv, li = jax.lax.top_k(jnp.asarray(x), k)
    np.testing.assert_array_equal(np.asarray(tv), np.asarray(lv))
    np.testing.assert_array_equal(np.asarray(ti), np.asarray(li))


@pytest.mark.parametrize("cfg", CELLS)
@pytest.mark.parametrize("dtype", WIDE_DTYPES)
@pytest.mark.parametrize("n", [300, 1500])  # direct path + partial round
def test_topk_wide_dtypes_matches_lax(rng, cfg, dtype, n):
    """topk over the codec satellites (two-word 64-bit, bf16, bool —
    bool is ALL ties: pure index-tiebreak conformance)."""
    k = 16
    with dtype_ctx(dtype):
        x = make_jnp_keys(dtype, n, rng, signed_zero=False)
        tv, ti = partial_sort.topk(x, k, cfg)
        lv, li = jax.lax.top_k(x, k)
        got = (npc(tv), np.asarray(ti))
        want = (npc(lv), np.asarray(li))
    np.testing.assert_array_equal(got[0], want[0])
    np.testing.assert_array_equal(got[1], want[1])


@pytest.mark.parametrize("cfg", CELLS)
@pytest.mark.parametrize("dtype", ["int32", "float32"])
@pytest.mark.parametrize("n", [300, 1500])  # direct path + partial round
def test_topk_batched_matches_lax(rng, cfg, dtype, n):
    b, k = 5, 16
    if dtype == "int32":
        x = rng.integers(0, 40, (b, n)).astype(np.int32)  # heavy ties
    else:
        x = rng.normal(size=(b, n)).astype(np.float32)
    tv, ti = partial_sort.topk_batched(jnp.asarray(x), k, cfg)
    lv, li = jax.lax.top_k(jnp.asarray(x), k)
    np.testing.assert_array_equal(np.asarray(tv), np.asarray(lv))
    np.testing.assert_array_equal(np.asarray(ti), np.asarray(li))


@pytest.mark.parametrize("cfg", CELLS)
@pytest.mark.parametrize("dtype", WIDE_DTYPES)
def test_topk_batched_wide_dtypes_matches_lax(rng, cfg, dtype):
    b, k, n = 5, 16, 1500
    with dtype_ctx(dtype):
        x = jnp.stack([make_jnp_keys(dtype, n, rng, signed_zero=False)
                       for _ in range(b)])
        tv, ti = partial_sort.topk_batched(x, k, cfg)
        lv, li = jax.lax.top_k(x, k)
        got = (npc(tv), np.asarray(ti))
        want = (npc(lv), np.asarray(li))
    np.testing.assert_array_equal(got[0], want[0])
    np.testing.assert_array_equal(got[1], want[1])


# ----------------------------------------------------------------------
# Key-codec property: encode/decode is an order-preserving bijection
# ----------------------------------------------------------------------


@pytest.mark.parametrize("dtype", ALL_DTYPES + ["float16", "int16", "int8",
                                                "uint16", "uint8"])
@pytest.mark.parametrize("order", ORDERS)
def test_codec_roundtrip_and_order(rng, dtype, order):
    """For every codec dtype and both orders:

      * decode(encode(x)) == x elementwise (bijection on values;
        NaN == NaN under assert_array_equal);
      * lexicographic unsigned order of the encoded words + index
        tiebreak reproduces jnp's stable (arg)sort exactly
        (order preservation), signed zeros excluded as ties.
    """
    from repro.core.key_codec import codec_for

    desc = order == "desc"
    n = 403
    with dtype_ctx(dtype):
        if dtype in ("float16", "int16", "int8", "uint16", "uint8"):
            base = rng.normal(size=n).astype(np.float32) * 100
            x = jnp.asarray(base).astype(dtype)
        else:
            x = make_jnp_keys(dtype, n, rng, signed_zero=False)
        codec = codec_for(x.dtype, desc)
        assert codec.dtype == x.dtype and codec.num_words in (1, 2)
        words = codec.encode(x)
        assert len(words) == codec.num_words
        assert all(w.dtype == jnp.uint32 and w.shape == x.shape
                   for w in words)
        back = codec.decode(words)
        assert back.dtype == x.dtype
        np.testing.assert_array_equal(npc(back), npc(x))
        # Order preservation: lexsort(words, index) == stable argsort.
        wnp = [np.asarray(w) for w in words]
        perm = np.lexsort(tuple([np.arange(n)] + list(reversed(wnp))))
        want = np.asarray(jnp.argsort(x, stable=True, descending=desc))
    np.testing.assert_array_equal(perm, want)
